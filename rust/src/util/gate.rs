//! The CI performance gate: compares a fresh `BENCH_hot_path.json` run
//! against the committed baseline and fails on regressions.
//!
//! Driven by the `memsgd bench-gate` subcommand from the `bench-gate`
//! job in `.github/workflows/ci.yml`; the comparison itself is a pure
//! function over parsed rows so the regression policy is unit-tested
//! (including the canary: an injected 2× slowdown must fail).
//!
//! ## Policy
//!
//! * Comparisons are **calibration-normalized**: each file's `p50_ns` is
//!   divided by that same file's calibration-case `p50_ns` before the
//!   ratio is taken. CI machines differ run to run; dividing by a fixed
//!   reference case measured in the same process cancels the machine
//!   factor, so the gate tracks *relative* regressions (a case getting
//!   slower than the rest of the suite) rather than runner lottery.
//! * A case regresses when `fresh_norm / base_norm >` the tolerance:
//!   **1.25** (the ">25% median regression" budget) for measured
//!   baseline rows, widened to **4.0** for rows marked `"estimated":
//!   true` (hand-seeded baselines that have never been measured on real
//!   hardware — see `BENCH_hot_path.json` provenance in the README).
//! * **Speedup invariants** are machine-independent claims checked on
//!   the fresh run alone — the sparse local step must be ≥ 5× faster
//!   than the dense one at the RCV1 shape (`d/nnz ≈ 470`), and the
//!   active-set phase sync (`O(touched)`, ~100 touched coordinates)
//!   must be ≥ 5× faster than the dense `O(d)` sync at d = 47 236. A
//!   missing invariant case is a failure: silently skipping it would
//!   un-gate the claim.
//! * Cases present on only one side produce warnings, not failures, so
//!   adding or retiring bench cases doesn't wedge CI — the next baseline
//!   refresh picks them up.

use anyhow::{bail, Result};

use crate::util::json::Json;

/// One parsed row of a perf-trajectory JSON-lines file (the
/// `BENCH_hot_path.json` schema documented in [`crate::util::bench`]).
#[derive(Clone, Debug, PartialEq)]
pub struct GateRow {
    pub bench: String,
    pub case: String,
    pub p50_ns: f64,
    /// Hand-seeded baseline row, never measured — widens the tolerance.
    pub estimated: bool,
}

/// Parse a JSON-lines perf file into gate rows. Strict: a malformed row
/// in a gating file is an error, not a skip.
pub fn parse_rows(text: &str) -> Result<Vec<GateRow>> {
    let mut rows = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let row = Json::parse(line)
            .map_err(|e| anyhow::anyhow!("line {}: {e:#}", lineno + 1))?;
        let p50 = row.req("p50_ns")?.as_f64()?;
        if p50.is_nan() || p50 <= 0.0 {
            bail!("line {}: p50_ns must be positive, got {p50}", lineno + 1);
        }
        rows.push(GateRow {
            bench: row.req("bench")?.as_str()?.to_string(),
            case: row.req("case")?.as_str()?.to_string(),
            p50_ns: p50,
            estimated: row
                .get("estimated")
                .map(|e| e.as_bool())
                .transpose()?
                .unwrap_or(false),
        });
    }
    Ok(rows)
}

/// The hot-path bench title (`benches/hot_path.rs` passes this to
/// [`crate::util::bench::Bench::new`]).
pub const HOT_PATH_BENCH: &str = "hot_path";

/// The calibration case: the plain dense-gradient kernel every other
/// case is normalized by. The bench runs it under exactly this name.
pub const CAL_CASE: &str = "grad only           dense d=2000";

/// Canonical name of the dense local-step case at minibatch size `bsz`
/// (RCV1 shape). Shared by the bench and the gate config so a rename
/// cannot silently desynchronize them.
pub fn local_step_dense_case(bsz: usize) -> String {
    format!("local step dense  B={bsz:<2} d=47236 nnz~100")
}

/// Canonical name of the sparse local-step case at minibatch size `bsz`.
pub fn local_step_sparse_case(bsz: usize) -> String {
    format!("local step sparse B={bsz:<2} d=47236 nnz~100")
}

/// Canonical name of the dense-route phase sync (the `O(d)` memory pass
/// + compressor scan) at the RCV1 dimension.
pub fn phase_sync_dense_case() -> String {
    "phase sync dense    top_10 d=47236".to_string()
}

/// Canonical name of the active-set phase sync at `active` touched
/// coordinates — the cases whose p50s demonstrate sync cost scaling
/// with the active set rather than d.
pub fn phase_sync_active_case(active: usize) -> String {
    format!("phase sync active   top_10 d=47236 a={active:<5}")
}

/// Canonical name of the wire-codec encode case for a top-10 sparse
/// payload at the RCV1 dimension (the threaded engines' per-upload
/// serialization cost). Regression-gated against the committed
/// baseline like every other case.
pub fn wire_encode_sparse_case() -> String {
    "wire encode sparse  top_10 d=47236".to_string()
}

/// Canonical name of the matching wire-codec decode case.
pub fn wire_decode_sparse_case() -> String {
    "wire decode sparse  top_10 d=47236".to_string()
}

/// Canonical name of the wire-codec encode case for a QSGD level
/// stream at the epsilon dimension.
pub fn wire_encode_qsgd_case() -> String {
    "wire encode qsgd    s=16 d=2000".to_string()
}

/// Canonical name of the matching QSGD decode case.
pub fn wire_decode_qsgd_case() -> String {
    "wire decode qsgd    s=16 d=2000".to_string()
}

/// Canonical name of the composed-compressor scan case: one full
/// `qsgd:16(top_k:100)` compress (top-100 selection + per-coordinate
/// stochastic quantization) at the RCV1 dimension — the per-step cost a
/// composed method adds over the plain sparsifier.
pub fn composed_scan_case() -> String {
    "composed scan       top_100 qsgd16 d=47236".to_string()
}

/// Canonical name of the composed payload encode case (the native
/// `TAG_COMPOSED` frame: gamma deltas + sign bits + gamma levels).
pub fn composed_encode_case() -> String {
    "composed encode     top_100 qsgd16 d=47236".to_string()
}

/// Canonical name of the matching composed payload decode case.
pub fn composed_decode_case() -> String {
    "composed decode     top_100 qsgd16 d=47236".to_string()
}

/// Canonical name of the TCP encode→socket→decode round-trip case for a
/// top-10 sparse payload at the RCV1 dimension — the full per-message
/// cost of the cluster runtime's data plane (payload encode, length
/// framing, a localhost kernel-socket hop, frame read, payload decode).
pub fn tcp_roundtrip_sparse_case() -> String {
    "tcp roundtrip sparse top_10 d=47236".to_string()
}

/// Canonical name of the matching TCP round-trip case for a QSGD level
/// stream at the epsilon dimension.
pub fn tcp_roundtrip_qsgd_case() -> String {
    "tcp roundtrip qsgd   s=16 d=2000".to_string()
}

/// Canonical name of the server-dispatch case: one poll-readiness
/// chunk carrying 8 framed top-10 uploads pushed through the resumable
/// `FrameAssembler` and the typed wire decoder — the per-wakeup cost
/// of the event-driven cluster server's data plane.
pub fn server_dispatch_case() -> String {
    "server dispatch 8up  top_10 d=47236".to_string()
}

/// Canonical name of the ring-merge case: folding `w` top-10 sparse
/// partials into one `RingPartial` aggregate and materializing the
/// mixed update, at the RCV1 dimension — the per-round merge cost every
/// all-reduce hop pays (the sparse-aware fold that replaces the
/// parameter server's aggregation slot).
pub fn ring_merge_sparse_case(w: usize) -> String {
    format!("ring merge sparse  W={w:<2} top_10 d=47236")
}

/// Canonical name of the ring-merge case with one dense contribution in
/// the mix — the spill path: the sparse partial is re-anchored into a
/// dense buffer in fixed node-id fold order.
pub fn ring_merge_mixed_case(w: usize) -> String {
    format!("ring merge mixed   W={w:<2} top_10 d=47236")
}

/// Canonical name of the cluster `SNAPSHOT` encode case: framing the
/// full dense model at the RCV1 dimension — the frame a restarted or
/// rejoin-serving server pushes to re-sync a replica.
pub fn snapshot_encode_case() -> String {
    "snapshot encode     dense d=47236".to_string()
}

/// Canonical name of the matching `SNAPSHOT` decode case (the rejoining
/// worker's cost to seed its replica from the frame).
pub fn snapshot_decode_case() -> String {
    "snapshot decode     dense d=47236".to_string()
}

/// Canonical name of the degraded-quorum server fold: one sync round's
/// aggregation of 7 live top-10 uploads out of 8 node slots (the dead
/// slot skipped, quorum mean at `1/7`) — the drop-round policy's
/// steady-state hot path.
pub fn server_fold_quorum_case() -> String {
    "server fold 7of8    top_10 d=47236".to_string()
}

/// A fresh-run-only invariant: `slow_case` must be at least `min_ratio`
/// × slower than `fast_case` (both in the same bench).
#[derive(Clone, Debug)]
pub struct SpeedupCheck {
    pub slow_case: String,
    pub fast_case: String,
    pub min_ratio: f64,
}

/// Gate policy knobs.
#[derive(Clone, Debug)]
pub struct GateConfig {
    /// `(bench, case)` used to normalize out the machine factor.
    pub calibration: (&'static str, &'static str),
    /// Regression tolerance against measured baseline rows (1.25 =
    /// "fail on >25% median regression").
    pub tolerance: f64,
    /// Widened tolerance against `estimated` baseline rows.
    pub tolerance_estimated: f64,
    /// Raw (un-normalized) band for the calibration case itself, which
    /// the normalized comparison is inherently blind to (its own ratio
    /// is identically 1.0). Wide enough to absorb runner variance, but
    /// a catastrophic regression of the calibration kernel still fails
    /// — unless the baseline row is `estimated` (then it only warns).
    pub calibration_band: f64,
    /// Machine-independent speedup invariants on the fresh run.
    pub speedups: Vec<SpeedupCheck>,
}

/// The hot-path policy: normalize by the plain dense gradient case, 25%
/// regression budget, and the two sparse-pipeline payoffs — the sparse
/// local step at the RCV1 shape (d = 47 236, nnz ≈ 100, d/nnz ≈ 470)
/// must be ≥ 5× faster than the dense local step, and the active-set
/// phase sync at ~100 touched coordinates must be ≥ 5× faster than the
/// dense `O(d)` sync.
pub fn hot_path_config() -> GateConfig {
    GateConfig {
        calibration: (HOT_PATH_BENCH, CAL_CASE),
        tolerance: 1.25,
        tolerance_estimated: 4.0,
        calibration_band: 8.0,
        speedups: vec![
            SpeedupCheck {
                slow_case: local_step_dense_case(1),
                fast_case: local_step_sparse_case(1),
                min_ratio: 5.0,
            },
            SpeedupCheck {
                slow_case: phase_sync_dense_case(),
                fast_case: phase_sync_active_case(100),
                min_ratio: 5.0,
            },
        ],
    }
}

/// Outcome of one gate evaluation.
#[derive(Debug, Default)]
pub struct GateReport {
    /// Human-readable per-case verdict lines (always populated).
    pub lines: Vec<String>,
    /// Hard failures — non-empty means the gate (and CI job) fails.
    pub failures: Vec<String>,
    /// Soft notices (cases missing on one side, no calibration, ...).
    pub warnings: Vec<String>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

fn find<'a>(rows: &'a [GateRow], bench: &str, case: &str) -> Option<&'a GateRow> {
    // Last occurrence wins, mirroring the writer's latest-wins dedupe.
    rows.iter().rev().find(|r| r.bench == bench && r.case == case)
}

/// Compare a fresh run against the committed baseline under `cfg`.
/// Pure — all I/O (and the process exit code) lives in the CLI wrapper.
pub fn compare(baseline: &[GateRow], fresh: &[GateRow], cfg: &GateConfig) -> GateReport {
    let mut report = GateReport::default();
    let (cal_bench, cal_case) = cfg.calibration;
    let cal = match (find(baseline, cal_bench, cal_case), find(fresh, cal_bench, cal_case)) {
        (Some(b), Some(f)) => {
            // Normalization is blind to the calibration case itself, so
            // hold its raw time to a loose machine-variance band.
            let raw = f.p50_ns / b.p50_ns;
            if raw > cfg.calibration_band || raw < 1.0 / cfg.calibration_band {
                let msg = format!(
                    "calibration case '{cal_bench}/{cal_case}' raw p50 moved {raw:.2}x \
                     (band {:.0}x)",
                    cfg.calibration_band
                );
                if b.estimated {
                    report.warnings.push(format!("{msg}; estimated baseline — not failing"));
                } else {
                    report.failures.push(msg);
                }
            }
            Some((b.p50_ns, f.p50_ns))
        }
        _ => {
            report.warnings.push(format!(
                "calibration case '{cal_bench}/{cal_case}' missing on one side; \
                 comparing raw (machine-dependent) p50s"
            ));
            None
        }
    };
    let normalize = |p50: f64, cal_p50: f64| p50 / cal_p50;

    for base_row in baseline {
        let Some(fresh_row) = find(fresh, &base_row.bench, &base_row.case) else {
            report.warnings.push(format!(
                "baseline case '{}/{}' not produced by the fresh run",
                base_row.bench, base_row.case
            ));
            continue;
        };
        let (base_val, fresh_val) = match cal {
            Some((bc, fc)) => (normalize(base_row.p50_ns, bc), normalize(fresh_row.p50_ns, fc)),
            None => (base_row.p50_ns, fresh_row.p50_ns),
        };
        let ratio = fresh_val / base_val;
        let tol = if base_row.estimated { cfg.tolerance_estimated } else { cfg.tolerance };
        let verdict = if ratio > tol { "FAIL" } else { "ok" };
        let case_id = format!("{}/{}", base_row.bench, base_row.case);
        report.lines.push(format!(
            "{verdict:>4}  {case_id:<48} ratio {ratio:>7.3} (tolerance {tol:.2}{})",
            if base_row.estimated { ", estimated baseline" } else { "" },
        ));
        if ratio > tol {
            report.failures.push(format!(
                "'{}/{}' regressed {ratio:.2}x vs baseline (tolerance {tol:.2}x)",
                base_row.bench, base_row.case
            ));
        }
    }

    for fresh_row in fresh {
        if find(baseline, &fresh_row.bench, &fresh_row.case).is_none() {
            report.warnings.push(format!(
                "new case '{}/{}' has no baseline row yet (commit a refreshed \
                 BENCH_hot_path.json to start gating it)",
                fresh_row.bench, fresh_row.case
            ));
        }
    }

    for check in &cfg.speedups {
        let slow = find(fresh, cal_bench, &check.slow_case);
        let fast = find(fresh, cal_bench, &check.fast_case);
        match (slow, fast) {
            (Some(s), Some(f)) if s.estimated || f.estimated => {
                report.failures.push(format!(
                    "speedup invariant cases '{}' / '{}' are estimated rows — the fresh \
                     side must be freshly measured (pass a fresh-rows-only file)",
                    check.slow_case, check.fast_case
                ));
            }
            (Some(s), Some(f)) => {
                let ratio = s.p50_ns / f.p50_ns;
                let ok = ratio >= check.min_ratio;
                report.lines.push(format!(
                    "{:>4}  speedup '{}' vs '{}': {ratio:.1}x (required >= {:.1}x)",
                    if ok { "ok" } else { "FAIL" },
                    check.fast_case,
                    check.slow_case,
                    check.min_ratio
                ));
                if !ok {
                    report.failures.push(format!(
                        "speedup invariant broken: '{}' only {ratio:.2}x faster than '{}' \
                         (required {:.1}x)",
                        check.fast_case, check.slow_case, check.min_ratio
                    ));
                }
            }
            _ => report.failures.push(format!(
                "speedup invariant cases missing from fresh run: '{}' / '{}'",
                check.slow_case, check.fast_case
            )),
        }
    }

    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(case: &str, p50: f64) -> GateRow {
        GateRow {
            bench: "hot_path".into(),
            case: case.into(),
            p50_ns: p50,
            estimated: false,
        }
    }

    fn cfg_no_speedups() -> GateConfig {
        GateConfig { speedups: Vec::new(), ..hot_path_config() }
    }

    const CAL: &str = CAL_CASE;

    #[test]
    fn identical_runs_pass() {
        let rows = vec![row(CAL, 1000.0), row("memsgd step", 4000.0)];
        let rep = compare(&rows, &rows, &cfg_no_speedups());
        assert!(rep.passed(), "{:?}", rep.failures);
        assert!(rep.warnings.is_empty(), "{:?}", rep.warnings);
    }

    #[test]
    fn injected_2x_slowdown_fails() {
        // The acceptance canary: halving a baseline row's time makes the
        // (unchanged) fresh run look 2x slower — the gate must fail.
        let fresh = vec![row(CAL, 1000.0), row("memsgd step", 4000.0)];
        let mut base = fresh.clone();
        base[1].p50_ns /= 2.0;
        let rep = compare(&base, &fresh, &cfg_no_speedups());
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("memsgd step"), "{:?}", rep.failures);
        assert!(rep.failures[0].contains("2.00x"), "{:?}", rep.failures);
    }

    #[test]
    fn within_tolerance_drift_passes_and_beyond_fails() {
        let base = vec![row(CAL, 1000.0), row("c", 1000.0)];
        let ok = vec![row(CAL, 1000.0), row("c", 1240.0)]; // +24%
        assert!(compare(&base, &ok, &cfg_no_speedups()).passed());
        let bad = vec![row(CAL, 1000.0), row("c", 1260.0)]; // +26%
        assert!(!compare(&base, &bad, &cfg_no_speedups()).passed());
    }

    #[test]
    fn calibration_cancels_uniform_machine_factor() {
        // A 3x slower machine slows every case 3x: normalized ratios are
        // 1.0 and the gate passes.
        let base = vec![row(CAL, 1000.0), row("c", 8000.0)];
        let fresh = vec![row(CAL, 3000.0), row("c", 24000.0)];
        assert!(compare(&base, &fresh, &cfg_no_speedups()).passed());
        // ...but a case-specific 2x slowdown on that machine still fails.
        let fresh_bad = vec![row(CAL, 3000.0), row("c", 48000.0)];
        assert!(!compare(&base, &fresh_bad, &cfg_no_speedups()).passed());
    }

    #[test]
    fn estimated_baseline_rows_get_the_wide_tolerance() {
        let mut base = vec![row(CAL, 1000.0), row("c", 1000.0)];
        base[1].estimated = true;
        let fresh3 = vec![row(CAL, 1000.0), row("c", 3900.0)]; // 3.9x < 4.0
        assert!(compare(&base, &fresh3, &cfg_no_speedups()).passed());
        let fresh5 = vec![row(CAL, 1000.0), row("c", 5000.0)]; // 5x > 4.0
        assert!(!compare(&base, &fresh5, &cfg_no_speedups()).passed());
    }

    #[test]
    fn missing_cases_warn_but_do_not_fail() {
        let base = vec![row(CAL, 1000.0), row("retired", 10.0)];
        let fresh = vec![row(CAL, 1000.0), row("brand-new", 10.0)];
        let rep = compare(&base, &fresh, &cfg_no_speedups());
        assert!(rep.passed(), "{:?}", rep.failures);
        assert_eq!(rep.warnings.len(), 2, "{:?}", rep.warnings);
    }

    /// Fresh rows satisfying both invariants at the given ratios.
    fn invariant_rows(local_ratio: f64, sync_ratio: f64) -> Vec<GateRow> {
        vec![
            row(CAL, 1000.0),
            row(&local_step_dense_case(1), 4_000.0 * local_ratio),
            row(&local_step_sparse_case(1), 4_000.0),
            row(&phase_sync_dense_case(), 2_000.0 * sync_ratio),
            row(&phase_sync_active_case(100), 2_000.0),
        ]
    }

    #[test]
    fn speedup_invariants_gate_the_sparse_payoffs() {
        let cfg = hot_path_config();
        assert_eq!(cfg.speedups.len(), 2, "local-step and phase-sync invariants");
        let base = vec![row(CAL, 1000.0)];
        // 10x on both: passes.
        assert!(compare(&base, &invariant_rows(10.0, 10.0), &cfg).passed());
        // Either invariant degrading below 5x fails on its own.
        let rep = compare(&base, &invariant_rows(3.0, 10.0), &cfg);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("local step"), "{:?}", rep.failures);
        let rep = compare(&base, &invariant_rows(10.0, 3.0), &cfg);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("phase sync"), "{:?}", rep.failures);
        // Missing invariant cases fail rather than silently skipping —
        // one failure per un-checkable invariant.
        let missing = vec![row(CAL, 1000.0)];
        let rep = compare(&base, &missing, &cfg);
        assert_eq!(rep.failures.len(), 2, "{:?}", rep.failures);
    }

    #[test]
    fn calibration_case_itself_is_held_to_the_raw_band() {
        // Uniform 3x machine drift is inside the 8x band: passes (see
        // calibration_cancels_uniform_machine_factor). A 10x blowup of
        // the calibration kernel cannot hide behind normalization.
        let base = vec![row(CAL, 1000.0), row("c", 2000.0)];
        let fresh = vec![row(CAL, 10_000.0), row("c", 20_000.0)];
        let rep = compare(&base, &fresh, &cfg_no_speedups());
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("calibration"), "{:?}", rep.failures);
        // ...but an estimated calibration baseline only warns.
        let mut base_est = base.clone();
        base_est[0].estimated = true;
        base_est[1].estimated = true;
        let rep = compare(&base_est, &fresh, &cfg_no_speedups());
        assert!(rep.passed(), "{:?}", rep.failures);
        assert!(rep.warnings.iter().any(|w| w.contains("not failing")), "{:?}", rep.warnings);
    }

    #[test]
    fn speedup_invariant_rejects_estimated_fresh_rows() {
        // Passing a merged baseline as the fresh file must not let an
        // invariant "pass" on never-measured estimated rows.
        let cfg = hot_path_config();
        let mut fresh = invariant_rows(10.0, 10.0);
        fresh[2].estimated = true; // the sparse local-step row
        let rep = compare(&[row(CAL, 1000.0)], &fresh, &cfg);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("estimated"), "{:?}", rep.failures);
    }

    #[test]
    fn parse_rows_reads_the_writer_schema() {
        let text = "\
{\"bench\":\"hot_path\",\"case\":\"a\",\"iters\":10,\"mean_ns\":5,\"p50_ns\":4,\"p95_ns\":9}\n\
{\"bench\":\"hot_path\",\"case\":\"b\",\"estimated\":true,\"iters\":1,\"mean_ns\":2,\"p50_ns\":2,\"p95_ns\":2}\n";
        let rows = parse_rows(text).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].p50_ns, 4.0);
        assert!(!rows[0].estimated);
        assert!(rows[1].estimated);
        assert!(parse_rows("{\"bench\":\"x\"}").is_err(), "missing fields rejected");
        assert!(parse_rows("not json").is_err());
        assert!(
            parse_rows("{\"bench\":\"x\",\"case\":\"c\",\"p50_ns\":0}").is_err(),
            "non-positive p50 rejected"
        );
    }
}
