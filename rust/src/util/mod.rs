//! Crate-internal utility layer.
//!
//! This build is fully offline and only the `xla` crate's vendored closure
//! is available, so the supporting machinery a crate would normally pull
//! from crates.io is implemented here instead:
//!
//! * [`prng`] — deterministic, splittable PRNG (xoshiro256++) with normal /
//!   bernoulli / choose-k sampling (replaces `rand`).
//! * [`json`] — a small JSON value model with parser and writer (replaces
//!   `serde_json`); used for the artifact manifest and experiment configs.
//! * [`cli`] — `--flag value` argument parsing for the `memsgd` binary
//!   (replaces `clap`).
//! * [`bench`] — a measurement harness with warmup, repetitions and
//!   percentile reporting (replaces `criterion`; all `benches/` use it).
//! * [`gate`] — the CI performance gate comparing fresh `bench` JSON
//!   against the committed `BENCH_hot_path.json` baseline.
//! * [`select`] — the heap-based top-k selection engine (dense and
//!   active-set scans, lowest-index tie-breaking).
//! * [`check`] — a miniature property-testing loop (replaces `proptest`)
//!   used by the invariant suites in `rust/tests/`.
//! * [`stats`] — mean / variance / percentile helpers for metrics.

pub mod bench;
pub mod check;
pub mod cli;
pub mod gate;
pub mod json;
pub mod prng;
pub mod select;
pub mod stats;
