//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Every stochastic component in the crate (rand-k sampling, QSGD rounding,
//! synthetic data generation, worker seeds) draws from this generator, so
//! every experiment is reproducible from a single `u64` seed. The
//! [`Prng::split`] operation derives statistically independent child
//! streams for parallel workers without sharing state.

/// xoshiro256++ generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Prng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Prng { s }
    }

    /// Derive an independent child stream (for worker `w`, say).
    pub fn split(&mut self, stream: u64) -> Prng {
        Prng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Raw internal state, for checkpointing.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a checkpointed state (must have come
    /// from [`Prng::state`]; the all-zero state is invalid for xoshiro).
    pub fn from_state(s: [u64; 4]) -> Prng {
        assert!(s.iter().any(|&v| v != 0), "all-zero xoshiro state");
        Prng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's rejection method (no
    /// modulo bias).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let (mut hi, mut lo) = mul_u64(self.next_u64(), n);
        if lo < n {
            // Reject the tiny biased band at the bottom of the range.
            let threshold = n.wrapping_neg() % n;
            while lo < threshold {
                let (h, l) = mul_u64(self.next_u64(), n);
                hi = h;
                lo = l;
            }
        }
        hi as usize
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached second value).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        // Box–Muller without caching: two uniforms per call. Simple,
        // branch-free, and the gradient math dominates anyway.
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Standard normal as `f32`.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm),
    /// appended to `out` in unspecified order. `O(k)` expected time.
    pub fn sample_distinct(&mut self, n: usize, k: usize, out: &mut Vec<u32>) {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        out.clear();
        if k == 0 {
            return;
        }
        // For small k relative to n, Floyd's with linear membership check
        // on the (tiny) output vector beats a HashSet; for large k, do a
        // partial Fisher-Yates over a scratch index range.
        if k * 16 <= n {
            for j in (n - k)..n {
                let t = self.below(j + 1) as u32;
                if out.contains(&t) {
                    out.push(j as u32);
                } else {
                    out.push(t);
                }
            }
        } else {
            let mut idx: Vec<u32> = (0..n as u32).collect();
            for i in 0..k {
                let j = i + self.below(n - i);
                idx.swap(i, j);
            }
            out.extend_from_slice(&idx[..k]);
        }
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Prng::new(7);
        let mut c1 = root.split(1);
        let mut c2 = root.split(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Prng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Prng::new(5);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Prng::new(13);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        assert!((28_000..32_000).contains(&hits), "hits={hits}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Prng::new(17);
        let mut out = Vec::new();
        for &(n, k) in &[(100usize, 3usize), (100, 50), (100, 100), (10, 0), (1, 1), (47236, 30)] {
            r.sample_distinct(n, k, &mut out);
            assert_eq!(out.len(), k, "n={n} k={k}");
            let mut sorted = out.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates for n={n} k={k}");
            assert!(out.iter().all(|&i| (i as usize) < n));
        }
    }

    #[test]
    fn sample_distinct_covers_all_indices_eventually() {
        let mut r = Prng::new(19);
        let mut out = Vec::new();
        let mut seen = [false; 20];
        for _ in 0..2_000 {
            r.sample_distinct(20, 2, &mut out);
            for &i in &out {
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Prng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
