//! Top-k index selection: **one engine, one tie rule**.
//!
//! The top-k compressor needs the k coordinates of largest magnitude.
//! Sorting is O(d log d); the bounded min-heap here is O(d + m·log k)
//! where m is the number of heap displacements — ≈ O(d) on the
//! compression hot path — and, unlike quickselect, has no pathological
//! tie behaviour: Mem-SGD's `m + ηg` vectors are full of exactly-equal
//! entries (zeros early on), which degrade Lomuto/Hoare partitions to
//! O(d²). (A quickselect variant used to live here as a second engine;
//! it was removed when the active-set scan landed — two engines with
//! different tie orders would be a latent bit-identity bug.)
//!
//! ## Tie-breaking contract
//!
//! Equal magnitudes are resolved toward the **lowest index**: the
//! selected set is exactly "sort by (|x| descending, index ascending),
//! take the first k" — a deterministic function of the values alone,
//! independent of scan order. That order-independence is load-bearing:
//! [`top_k_in_subset`] visits coordinates in arbitrary (active-set)
//! order and must select exactly what the dense ascending scan selects
//! (`compress::Compressor::compress_active`'s bit-identity contract).
//! Implemented by packing `(magnitude, !index)` into one `u64` key, so
//! a plain integer comparison prefers larger magnitude first and lower
//! index second.

/// Integer key whose order matches |x| for all non-NaN floats.
#[inline(always)]
fn mag_bits(x: f32) -> u32 {
    x.to_bits() & 0x7fff_ffff
}

/// Packed comparison key: magnitude in the high bits, bitwise-NOT index
/// in the low bits — larger key ⇔ (larger |x|, then lower index).
#[inline(always)]
fn pack(mag: u32, idx: u32) -> u64 {
    ((mag as u64) << 32) | (!idx) as u64
}

#[inline(always)]
fn unpack_idx(key: u64) -> u32 {
    !(key as u32)
}

/// Return the indices of the `k` largest-|x| coordinates of a dense
/// vector, using `scratch` as the reusable output buffer. Ties go to
/// the lowest index (see the module docs).
///
/// Convenience wrapper that allocates its own heap; hot paths use
/// [`top_k_indices_with_heap`].
pub fn top_k_indices(x: &[f32], k: usize, scratch: &mut Vec<u32>) {
    let mut heap = Vec::new();
    top_k_indices_with_heap(x, k, &mut heap, scratch);
}

/// [`top_k_indices`] with a caller-owned heap scratch so the per-call
/// allocation disappears from the hot loop (§Perf iteration 6: the
/// `Vec::with_capacity(k)` inside the old scan cost ~8% of the top-k
/// step at d = 2000).
///
/// Implementation: a bounded min-heap of packed `(magnitude, !index)`
/// keys; heap\[0\] is the admission threshold. Most elements fail that
/// single well-predicted compare and never touch the heap, so the loop
/// runs at ~memory speed, helped by a chunked SIMD prefilter (§Perf
/// iteration 8): the per-chunk max of the integer magnitudes
/// vectorizes, and only chunks whose max beats the current admission
/// magnitude take the scalar branchy path. Equal-magnitude candidates
/// never displace an incumbent in this ascending scan (the incumbent
/// has the lower index), so the magnitude-only prefilter is exact.
pub fn top_k_indices_with_heap(x: &[f32], k: usize, heap: &mut Vec<u64>, scratch: &mut Vec<u32>) {
    let d = x.len();
    let k = k.min(d);
    scratch.clear();
    heap.clear();
    if k == 0 {
        return;
    }
    heap.reserve(k);
    // Warm-up: fill + heapify on the first k elements (scalar).
    for (i, &v) in x[..k].iter().enumerate() {
        heap.push(pack(mag_bits(v), i as u32));
    }
    for j in (0..k / 2).rev() {
        sift_down(heap, j);
    }
    const CHUNK: usize = 16;
    let mut i = k;
    while i + CHUNK <= d {
        let chunk = &x[i..i + CHUNK];
        let mut cmax = 0u32;
        for &v in chunk {
            cmax = cmax.max(mag_bits(v));
        }
        if cmax > (heap[0] >> 32) as u32 {
            for (j, &v) in chunk.iter().enumerate() {
                let key = pack(mag_bits(v), (i + j) as u32);
                if key > heap[0] {
                    heap[0] = key;
                    sift_down(heap, 0);
                }
            }
        }
        i += CHUNK;
    }
    for (j, &v) in x[i..].iter().enumerate() {
        let key = pack(mag_bits(v), (i + j) as u32);
        if key > heap[0] {
            heap[0] = key;
            sift_down(heap, 0);
        }
    }
    scratch.extend(heap.iter().map(|&key| unpack_idx(key)));
}

/// Top-k over an **index subset**: select the `k` indices of `subset`
/// with the largest `|vals[j]|`, ties to the lowest index, into `out`
/// (unordered). `subset` must hold unique in-bounds indices; its order
/// is irrelevant — the full-key admission test makes the result a
/// deterministic function of the (value, index) pairs alone, so it
/// matches the dense scan on any vector that is zero outside `subset`
/// (as long as the dense selection never needs those zeros, i.e. the
/// subset contains at least `k` coordinates of the top-k's magnitude
/// class — `compress::TopK::compress_active` handles the remainder by
/// explicit zero-padding).
pub fn top_k_in_subset(
    vals: &[f32],
    subset: &[u32],
    k: usize,
    heap: &mut Vec<u64>,
    out: &mut Vec<u32>,
) {
    let k = k.min(subset.len());
    out.clear();
    heap.clear();
    if k == 0 {
        return;
    }
    heap.reserve(k);
    for &j in &subset[..k] {
        heap.push(pack(mag_bits(vals[j as usize]), j));
    }
    for i in (0..k / 2).rev() {
        sift_down(heap, i);
    }
    for &j in &subset[k..] {
        let key = pack(mag_bits(vals[j as usize]), j);
        if key > heap[0] {
            heap[0] = key;
            sift_down(heap, 0);
        }
    }
    out.extend(heap.iter().map(|&key| unpack_idx(key)));
}

/// Fused `v = m + η·g` build + top-k selection in one pass. **Measured
/// 35% slower than the two-pass form and NOT used on the hot path**
/// (§Perf iteration 7, reverted): the heap admission branch forces the
/// whole combined loop scalar, losing the v-build's SIMD fma. Kept (and
/// raced in `benches/compressors.rs`) as the recorded evidence for that
/// decision. Output contract matches [`top_k_indices_with_heap`] over
/// the computed `v`, including the lowest-index tie rule.
pub fn top_k_fused(
    m: &[f32],
    grad: &[f32],
    eta: f32,
    v_out: &mut [f32],
    k: usize,
    heap: &mut Vec<u64>,
    scratch: &mut Vec<u32>,
) {
    let d = v_out.len();
    let k = k.min(d);
    scratch.clear();
    heap.clear();
    if k == 0 {
        for i in 0..d {
            v_out[i] = m[i] + eta * grad[i];
        }
        return;
    }
    heap.reserve(k);
    for i in 0..d {
        let v = m[i] + eta * grad[i];
        v_out[i] = v;
        let key = pack(mag_bits(v), i as u32);
        if heap.len() < k {
            heap.push(key);
            if heap.len() == k {
                for j in (0..k / 2).rev() {
                    sift_down(heap, j);
                }
            }
        } else if key > heap[0] {
            heap[0] = key;
            sift_down(heap, 0);
        }
    }
    scratch.extend(heap.iter().map(|&key| unpack_idx(key)));
}

#[inline]
fn sift_down(heap: &mut [u64], mut j: usize) {
    let n = heap.len();
    loop {
        let l = 2 * j + 1;
        if l >= n {
            return;
        }
        let r = l + 1;
        let smallest = if r < n && heap[r] < heap[l] { r } else { l };
        if heap[smallest] < heap[j] {
            heap.swap(j, smallest);
            j = smallest;
        } else {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    /// Sort-based oracle for the documented contract: (|x| descending,
    /// index ascending), first k, returned sorted by index.
    fn oracle_top_k(x: &[f32], candidates: &[u32], k: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = candidates.to_vec();
        idx.sort_by(|&a, &b| {
            let (ma, mb) = (x[a as usize].abs(), x[b as usize].abs());
            mb.partial_cmp(&ma).unwrap().then(a.cmp(&b))
        });
        idx.truncate(k.min(idx.len()));
        idx.sort_unstable();
        idx
    }

    fn sorted(v: &[u32]) -> Vec<u32> {
        let mut s = v.to_vec();
        s.sort_unstable();
        s
    }

    /// Quantized random value: draws from a small set so exact-magnitude
    /// ties are common — the regime where the tie rule matters.
    fn tied_value(rng: &mut Prng) -> f32 {
        let mag = rng.below(5) as f32 * 0.5; // {0, 0.5, 1, 1.5, 2}
        if rng.below(2) == 0 {
            mag
        } else {
            -mag
        }
    }

    #[test]
    fn prop_matches_oracle_exactly_with_duplicates() {
        // Exact set equality against the sort oracle — not just the
        // magnitude multiset — on tie-heavy vectors, over the edge
        // cardinalities k ∈ {0, 1, len−1, len} plus random k.
        let mut rng = Prng::new(1);
        let mut scratch = Vec::new();
        let mut heap = Vec::new();
        for trial in 0..300 {
            let d = 1 + rng.below(200);
            let x: Vec<f32> = (0..d).map(|_| tied_value(&mut rng)).collect();
            let all: Vec<u32> = (0..d as u32).collect();
            for k in [0usize, 1, d.saturating_sub(1), d, 1 + rng.below(d)] {
                top_k_indices_with_heap(&x, k, &mut heap, &mut scratch);
                assert_eq!(
                    sorted(&scratch),
                    oracle_top_k(&x, &all, k),
                    "trial={trial} d={d} k={k}"
                );
            }
        }
    }

    #[test]
    fn prop_matches_oracle_on_smooth_random_vectors() {
        let mut rng = Prng::new(2);
        let mut scratch = Vec::new();
        for trial in 0..200 {
            let d = 1 + rng.below(300);
            let k = 1 + rng.below(d);
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let all: Vec<u32> = (0..d as u32).collect();
            top_k_indices(&x, k, &mut scratch);
            assert_eq!(sorted(&scratch), oracle_top_k(&x, &all, k), "trial={trial} d={d} k={k}");
        }
    }

    #[test]
    fn adversarial_patterns_match_oracle() {
        // The shapes that degrade partition-based selection: sorted
        // ascending/descending, all-equal plateaus, and block plateaus.
        let mut scratch = Vec::new();
        let mut heap = Vec::new();
        let d = 128usize;
        let patterns: Vec<Vec<f32>> = vec![
            (0..d).map(|i| i as f32).collect(),
            (0..d).map(|i| (d - i) as f32).collect(),
            vec![1.0; d],
            (0..d).map(|i| if i / 16 % 2 == 0 { 2.0 } else { -2.0 }).collect(),
            (0..d).map(|i| ((i % 3) as f32 - 1.0) * 4.0).collect(),
        ];
        let all: Vec<u32> = (0..d as u32).collect();
        for (p, x) in patterns.iter().enumerate() {
            for k in [1usize, 2, 16, d / 2, d - 1, d] {
                top_k_indices_with_heap(x, k, &mut heap, &mut scratch);
                assert_eq!(sorted(&scratch), oracle_top_k(x, &all, k), "pattern={p} k={k}");
            }
        }
    }

    #[test]
    fn ties_resolve_to_lowest_indices() {
        // The documented rule, pinned: all-equal magnitudes select the
        // lowest k indices exactly.
        let x = [1.0f32; 64];
        let mut scratch = Vec::new();
        top_k_indices(&x, 7, &mut scratch);
        assert_eq!(sorted(&scratch), vec![0, 1, 2, 3, 4, 5, 6]);
        // Mixed signs tie too (magnitude comparison).
        let y = [-2.0f32, 2.0, 2.0, -2.0, 0.5];
        top_k_indices(&y, 2, &mut scratch);
        assert_eq!(sorted(&scratch), vec![0, 1]);
    }

    #[test]
    fn k_zero_and_k_full() {
        let x = [3.0f32, -1.0, 2.0];
        let mut scratch = Vec::new();
        top_k_indices(&x, 0, &mut scratch);
        assert!(scratch.is_empty());
        top_k_indices(&x, 3, &mut scratch);
        assert_eq!(sorted(&scratch), vec![0, 1, 2]);
        top_k_indices(&x, 10, &mut scratch);
        assert_eq!(scratch.len(), 3);
    }

    #[test]
    fn negative_magnitudes_use_abs() {
        let x = [-10.0f32, 1.0, -5.0, 0.5];
        let mut scratch = Vec::new();
        top_k_indices(&x, 2, &mut scratch);
        assert_eq!(sorted(&scratch), vec![0, 2]);
    }

    #[test]
    fn subset_scan_matches_dense_scan_on_sparse_vectors() {
        // When the subset covers every nonzero and k ≤ #nonzeros, the
        // subset scan must equal the dense scan exactly — the active-set
        // compressor equivalence in miniature.
        let mut rng = Prng::new(3);
        let mut heap = Vec::new();
        let (mut dense_out, mut subset_out) = (Vec::new(), Vec::new());
        for trial in 0..200 {
            let d = 8 + rng.below(300);
            let nnz = 1 + rng.below(d.min(40));
            let mut x = vec![0.0f32; d];
            let mut support: Vec<u32> = Vec::new();
            while support.len() < nnz {
                let j = rng.below(d) as u32;
                if x[j as usize] == 0.0 {
                    let mut v = tied_value(&mut rng);
                    if v == 0.0 {
                        v = 1.0;
                    }
                    x[j as usize] = v;
                    support.push(j);
                }
            }
            // Extra touched-but-zero coordinates must not disturb the
            // selection while k ≤ #nonzeros (zero loses every compare).
            let extra = rng.below(4);
            for _ in 0..extra {
                let j = rng.below(d) as u32;
                if x[j as usize] == 0.0 && !support.contains(&j) {
                    support.push(j);
                }
            }
            rng.shuffle(&mut support);
            let k = 1 + rng.below(nnz);
            top_k_indices_with_heap(&x, k, &mut heap, &mut dense_out);
            top_k_in_subset(&x, &support, k, &mut heap, &mut subset_out);
            assert_eq!(sorted(&subset_out), sorted(&dense_out), "trial={trial} d={d} k={k}");
        }
    }

    #[test]
    fn subset_scan_is_order_independent() {
        let x = [0.0f32, 3.0, 3.0, -3.0, 1.0, 0.0, 3.0, 2.0];
        let mut heap = Vec::new();
        let mut out = Vec::new();
        let mut reference = Vec::new();
        let base = vec![1u32, 2, 3, 4, 6, 7];
        top_k_in_subset(&x, &base, 3, &mut heap, &mut reference);
        // Ties at |3.0|: indices 1, 2, 3, 6 — lowest three win.
        assert_eq!(sorted(&reference), vec![1, 2, 3]);
        let mut rng = Prng::new(4);
        for _ in 0..50 {
            let mut shuffled = base.clone();
            rng.shuffle(&mut shuffled);
            top_k_in_subset(&x, &shuffled, 3, &mut heap, &mut out);
            assert_eq!(sorted(&out), sorted(&reference), "order {shuffled:?}");
        }
    }

    #[test]
    fn subset_k_edges() {
        let x = [5.0f32, 0.0, 1.0, 2.0];
        let mut heap = Vec::new();
        let mut out = Vec::new();
        let subset = vec![0u32, 2, 3];
        top_k_in_subset(&x, &subset, 0, &mut heap, &mut out);
        assert!(out.is_empty());
        top_k_in_subset(&x, &subset, 3, &mut heap, &mut out);
        assert_eq!(sorted(&out), vec![0, 2, 3]);
        top_k_in_subset(&x, &subset, 10, &mut heap, &mut out);
        assert_eq!(out.len(), 3, "k caps at the subset size");
        top_k_in_subset(&x, &[], 2, &mut heap, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn fused_matches_two_pass_including_ties() {
        let mut rng = Prng::new(5);
        let (mut heap_a, mut heap_b) = (Vec::new(), Vec::new());
        let (mut out_a, mut out_b) = (Vec::new(), Vec::new());
        for _ in 0..100 {
            let d = 1 + rng.below(200);
            let k = 1 + rng.below(d);
            let m: Vec<f32> = (0..d).map(|_| tied_value(&mut rng)).collect();
            let g: Vec<f32> = (0..d).map(|_| tied_value(&mut rng)).collect();
            let mut v_two = vec![0.0f32; d];
            for i in 0..d {
                v_two[i] = m[i] + 0.5 * g[i];
            }
            top_k_indices_with_heap(&v_two, k, &mut heap_a, &mut out_a);
            let mut v_fused = vec![0.0f32; d];
            top_k_fused(&m, &g, 0.5, &mut v_fused, k, &mut heap_b, &mut out_b);
            assert_eq!(v_two, v_fused);
            assert_eq!(sorted(&out_a), sorted(&out_b));
        }
    }
}
