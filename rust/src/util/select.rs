//! In-place selection: the O(d) average-case engine behind `top_k`.
//!
//! The top-k compressor needs the k coordinates of largest magnitude.
//! Sorting is O(d log d); Hoare-style quickselect with median-of-three
//! pivots is O(d) average, and the compressor calls it every iteration,
//! so this is genuinely hot-path code (see benches/hot_path.rs).

/// Partition `items` (an index array) so the first `k` entries are the
/// indices with the largest `magnitude` values (unordered within the
/// prefix). `magnitude(i)` must be deterministic for the duration of the
/// call. O(len) average time, in place.
pub fn select_top_k_by<F: Fn(u32) -> f32>(items: &mut [u32], k: usize, magnitude: F) {
    if k == 0 || k >= items.len() {
        return;
    }
    let mut lo = 0usize;
    let mut hi = items.len();
    // Invariant: items[..lo] are all >= items[lo..hi] >= items[hi..] (by
    // magnitude), and the k-boundary lies in [lo, hi].
    while hi - lo > 1 {
        let p = partition(items, lo, hi, &magnitude);
        if p + 1 == k {
            return; // pivot is the k-th largest; prefix settled
        } else if p + 1 < k {
            lo = p + 1; // top-k boundary is to the right of the pivot
        } else {
            hi = p; // boundary is strictly left of the pivot
        }
    }
}

/// Hoare-ish partition around a median-of-three pivot, descending by
/// magnitude. Returns the final index of the pivot.
fn partition<F: Fn(u32) -> f32>(items: &mut [u32], lo: usize, hi: usize, magnitude: &F) -> usize {
    let len = hi - lo;
    debug_assert!(len >= 1);
    // Median of three (first, middle, last) as pivot, moved to `lo`.
    let mid = lo + len / 2;
    let (a, b, c) = (magnitude(items[lo]), magnitude(items[mid]), magnitude(items[hi - 1]));
    let pivot_idx = if (a >= b) == (a <= c) {
        lo
    } else if (b >= a) == (b <= c) {
        mid
    } else {
        hi - 1
    };
    items.swap(lo, pivot_idx);
    let pivot = magnitude(items[lo]);
    // Lomuto partition, descending: entries > pivot go left.
    let mut store = lo + 1;
    for i in (lo + 1)..hi {
        if magnitude(items[i]) > pivot {
            items.swap(i, store);
            store += 1;
        }
    }
    items.swap(lo, store - 1);
    store - 1
}

/// Return the indices of the `k` largest-|x| coordinates of a dense
/// vector, using `scratch` as the reusable output buffer.
///
/// Implementation: a bounded min-heap over (|value|, index). This is
/// O(d + m·log k) where m is the number of heap displacements — in
/// practice ≈ O(d) for the compression hot path — and, unlike
/// quickselect, has **no pathological tie behaviour**: Mem-SGD's
/// `m + ηg` vectors are full of exactly-equal entries (zeros early on),
/// which degrade Lomuto/Hoare partitions to O(d²). The quickselect in
/// [`select_top_k_by`] is kept for callers with k ≈ d (and is raced
/// against this heap in benches/compressors.rs).
pub fn top_k_indices(x: &[f32], k: usize, scratch: &mut Vec<u32>) {
    let mut heap = Vec::new();
    top_k_indices_with_heap(x, k, &mut heap, scratch);
}

/// [`top_k_indices`] with a caller-owned heap scratch so the per-call
/// allocation disappears from the hot loop (§Perf iteration 6: the
/// `Vec::with_capacity(k)` inside the old scan cost ~8% of the top-k
/// step at d = 2000).
pub fn top_k_indices_with_heap(
    x: &[f32],
    k: usize,
    heap: &mut Vec<(u32, u32)>,
    scratch: &mut Vec<u32>,
) {
    let d = x.len();
    let k = k.min(d);
    scratch.clear();
    heap.clear();
    if k == 0 {
        return;
    }
    // Min-heap of the k best seen so far, keyed by integer magnitude
    // (for non-NaN f32, |a| <= |b| ⇔ (a.bits & 0x7fffffff) <= (b.bits &
    // 0x7fffffff), so the scan stays in the integer pipeline). heap[0]
    // is the admission threshold; most elements fail that single
    // well-predicted compare and never touch the heap, so the loop runs
    // at ~memory speed. (A dedicated k=1 max-scan measured *slower* than
    // this loop — see benches/compressors.rs.)
    heap.reserve(k);
    // Warm-up: fill + heapify on the first k elements (scalar).
    let warm = k.min(d);
    for (i, &v) in x[..warm].iter().enumerate() {
        heap.push((mag_bits(v), i as u32));
    }
    if heap.len() == k {
        for j in (0..k / 2).rev() {
            sift_down(heap, j);
        }
    }
    // Main scan with a chunked SIMD prefilter (§Perf iteration 8): the
    // per-chunk max of the integer magnitudes vectorizes; only chunks
    // whose max beats the current admission threshold heap[0] take the
    // scalar branchy path. For the top-k of a long random vector almost
    // every chunk fails the single vector compare, so the scan runs at
    // SIMD reduction speed instead of scalar-compare speed.
    const CHUNK: usize = 16;
    let mut i = warm;
    while i + CHUNK <= d {
        let chunk = &x[i..i + CHUNK];
        let mut cmax = 0u32;
        for &v in chunk {
            cmax = cmax.max(mag_bits(v));
        }
        if cmax > heap[0].0 {
            for (j, &v) in chunk.iter().enumerate() {
                let m = mag_bits(v);
                if m > heap[0].0 {
                    heap[0] = (m, (i + j) as u32);
                    sift_down(heap, 0);
                }
            }
        }
        i += CHUNK;
    }
    for (j, &v) in x[i..].iter().enumerate() {
        let m = mag_bits(v);
        if m > heap[0].0 {
            heap[0] = (m, (i + j) as u32);
            sift_down(heap, 0);
        }
    }
    // d < k never reaches heapify; order is irrelevant either way.
    scratch.extend(heap.iter().map(|&(_, i)| i));
}

/// Fused `v = m + η·g` build + top-k selection in one pass. **Measured
/// 35% slower than the two-pass form and NOT used on the hot path**
/// (§Perf iteration 7, reverted): the heap admission branch forces the
/// whole combined loop scalar, losing the v-build's SIMD fma. Kept (and
/// raced in `benches/compressors.rs`) as the recorded evidence for that
/// decision. Output contract matches [`top_k_indices_with_heap`] over
/// the computed `v`.
pub fn top_k_fused(
    m: &[f32],
    grad: &[f32],
    eta: f32,
    v_out: &mut [f32],
    k: usize,
    heap: &mut Vec<(u32, u32)>,
    scratch: &mut Vec<u32>,
) {
    let d = v_out.len();
    let k = k.min(d);
    scratch.clear();
    heap.clear();
    heap.reserve(k);
    for i in 0..d {
        let v = m[i] + eta * grad[i];
        v_out[i] = v;
        let mb = mag_bits(v);
        if heap.len() < k {
            heap.push((mb, i as u32));
            if heap.len() == k {
                for j in (0..k / 2).rev() {
                    sift_down(heap, j);
                }
            }
        } else if mb > heap[0].0 {
            heap[0] = (mb, i as u32);
            sift_down(heap, 0);
        }
    }
    scratch.extend(heap.iter().map(|&(_, i)| i));
}

/// Integer key whose order matches |x| for all non-NaN floats.
#[inline(always)]
fn mag_bits(x: f32) -> u32 {
    x.to_bits() & 0x7fff_ffff
}

#[inline]
fn sift_down(heap: &mut [(u32, u32)], mut j: usize) {
    let n = heap.len();
    loop {
        let l = 2 * j + 1;
        if l >= n {
            return;
        }
        let r = l + 1;
        let smallest = if r < n && heap[r].0 < heap[l].0 { r } else { l };
        if heap[smallest].0 < heap[j].0 {
            heap.swap(j, smallest);
            j = smallest;
        } else {
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn brute_top_k(x: &[f32], k: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..x.len() as u32).collect();
        idx.sort_by(|&a, &b| {
            x[b as usize]
                .abs()
                .partial_cmp(&x[a as usize].abs())
                .unwrap()
        });
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }

    #[test]
    fn matches_brute_force_on_random_vectors() {
        let mut rng = Prng::new(1);
        let mut scratch = Vec::new();
        for trial in 0..200 {
            let d = 1 + rng.below(300);
            let k = 1 + rng.below(d);
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            top_k_indices(&x, k, &mut scratch);
            let mut got = scratch.clone();
            got.sort_unstable();
            // With possible magnitude ties, compare the magnitude multiset.
            let want = brute_top_k(&x, k);
            let mag = |v: &[u32]| {
                let mut m: Vec<f32> = v.iter().map(|&i| x[i as usize].abs()).collect();
                m.sort_by(|a, b| a.partial_cmp(b).unwrap());
                m
            };
            assert_eq!(mag(&got), mag(&want), "trial={trial} d={d} k={k}");
        }
    }

    #[test]
    fn k_zero_and_k_full() {
        let x = [3.0f32, -1.0, 2.0];
        let mut scratch = Vec::new();
        top_k_indices(&x, 0, &mut scratch);
        assert!(scratch.is_empty());
        top_k_indices(&x, 3, &mut scratch);
        let mut got = scratch.clone();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2]);
        top_k_indices(&x, 10, &mut scratch);
        assert_eq!(scratch.len(), 3);
    }

    #[test]
    fn ties_still_return_k_items() {
        let x = [1.0f32; 64];
        let mut scratch = Vec::new();
        top_k_indices(&x, 7, &mut scratch);
        assert_eq!(scratch.len(), 7);
        let mut s = scratch.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 7);
    }

    #[test]
    fn prefix_dominates_suffix() {
        let mut rng = Prng::new(2);
        for _ in 0..50 {
            let d = 2 + rng.below(500);
            let k = 1 + rng.below(d - 1);
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 10.0).collect();
            let mut idx: Vec<u32> = (0..d as u32).collect();
            select_top_k_by(&mut idx, k, |i| x[i as usize].abs());
            let min_in = idx[..k]
                .iter()
                .map(|&i| x[i as usize].abs())
                .fold(f32::INFINITY, f32::min);
            let max_out = idx[k..]
                .iter()
                .map(|&i| x[i as usize].abs())
                .fold(f32::NEG_INFINITY, f32::max);
            assert!(min_in >= max_out, "d={d} k={k} min_in={min_in} max_out={max_out}");
        }
    }

    #[test]
    fn negative_magnitudes_use_abs() {
        let x = [-10.0f32, 1.0, -5.0, 0.5];
        let mut scratch = Vec::new();
        top_k_indices(&x, 2, &mut scratch);
        let mut got = scratch.clone();
        got.sort_unstable();
        assert_eq!(got, vec![0, 2]);
    }
}
