//! Miniature property-testing harness (proptest replacement).
//!
//! A property is a closure from a seeded [`Prng`] to `Result<(), String>`;
//! [`check`] runs it across many derived seeds and reports the first
//! failing seed so the case can be replayed deterministically:
//!
//! ```ignore
//! check("top_k contraction", 500, |rng| {
//!     let d = 1 + rng.below(100);
//!     ...
//!     ensure(holds, format!("violated at d={d}"))
//! });
//! ```

use crate::util::prng::Prng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random instances of `prop`; panic with the failing seed and
/// message on the first violation. Seeds derive deterministically from the
/// property name so failures are replayable.
pub fn check<F: FnMut(&mut Prng) -> CaseResult>(name: &str, cases: usize, mut prop: F) {
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Prng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay one specific seed of a property (for debugging).
pub fn replay<F: FnMut(&mut Prng) -> CaseResult>(name: &str, seed: u64, mut prop: F) {
    let mut rng = Prng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed on replay (seed {seed:#x}): {msg}");
    }
}

/// Helper: turn a boolean condition into a `CaseResult`.
pub fn ensure(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Helper: approximate scalar equality with relative + absolute tolerance.
pub fn ensure_close(a: f64, b: f64, rtol: f64, atol: f64, label: &str) -> CaseResult {
    let tol = atol + rtol * b.abs().max(a.abs());
    ensure(
        (a - b).abs() <= tol,
        format!("{label}: {a} vs {b} (tol {tol})"),
    )
}

/// Helper: elementwise closeness of two f32 slices.
pub fn ensure_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32, label: &str) -> CaseResult {
    if a.len() != b.len() {
        return Err(format!("{label}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!("{label}[{i}]: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-true", 50, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_seed() {
        check("always-false", 10, |_| Err("nope".into()));
    }

    #[test]
    fn seeds_are_deterministic_per_name() {
        let mut first: Vec<u64> = Vec::new();
        check("det", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("det", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn ensure_close_tolerances() {
        assert!(ensure_close(1.0, 1.0 + 1e-9, 1e-6, 0.0, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-6, 0.0, "x").is_err());
        assert!(ensure_allclose(&[1.0, 2.0], &[1.0, 2.0], 0.0, 0.0, "v").is_ok());
        assert!(ensure_allclose(&[1.0], &[1.0, 2.0], 0.0, 0.0, "v").is_err());
        assert!(ensure_allclose(&[1.0], &[1.1], 1e-3, 0.0, "v").is_err());
    }
}
