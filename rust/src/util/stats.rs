//! Small numeric-summary helpers shared by metrics and benches.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (0.0 for empty input).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile in `[0, 100]` by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Euclidean norm of an f32 slice, accumulated in f64.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Squared Euclidean norm, accumulated in f64.
pub fn l2_norm_sq(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
}

/// Squared Euclidean distance between two equal-length slices.
pub fn l2_dist_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum()
}

/// Relative L2 error `|a - b| / max(|b|, eps)`.
pub fn rel_l2_err(a: &[f32], b: &[f32]) -> f64 {
    l2_dist_sq(a, b).sqrt() / l2_norm(b).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert!((stddev(&xs) - 1.25f64.sqrt()).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(percentile(&[7.0], 95.0), 7.0);
    }

    #[test]
    fn norms() {
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
        assert_eq!(l2_norm_sq(&[3.0, 4.0]), 25.0);
        assert_eq!(l2_dist_sq(&[1.0, 1.0], &[1.0, 2.0]), 1.0);
        assert!((rel_l2_err(&[1.0, 0.0], &[1.0, 0.0])).abs() < 1e-12);
        assert!(rel_l2_err(&[2.0], &[1.0]) == 1.0);
    }
}
