//! Tiny command-line parsing for the `memsgd` binary and the examples.
//!
//! Grammar: `memsgd <subcommand> [--key value]... [--flag]...`.
//! Values are parsed on demand with typed getters; unknown keys are
//! reported as errors so typos do not silently fall back to defaults.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed arguments: one optional subcommand plus `--key [value]` pairs.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .with_context(|| format!("expected --key, found '{tok}'"))?
                .to_string();
            if key.is_empty() {
                bail!("empty flag '--'");
            }
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    let val = it.next().unwrap();
                    args.kv.insert(key, val);
                }
                _ => args.flags.push(key),
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// String option with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string (no default).
    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.kv.get(key).cloned()
    }

    /// Typed numeric option with default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.kv.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    /// Boolean flag presence (`--verbose`).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated list option, e.g. `--k 1,2,3`.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>>
    where
        T: Clone,
        T::Err: std::fmt::Display,
    {
        self.mark(key);
        match self.kv.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<T>()
                        .map_err(|e| anyhow::anyhow!("--{key} '{s}': {e}"))
                })
                .collect(),
        }
    }

    /// Error if any provided option was never consumed by a getter — this
    /// catches typos like `--steeps 100`. Call after all getters.
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for key in self.kv.keys().chain(self.flags.iter()) {
            if !consumed.iter().any(|c| c == key) {
                bail!("unknown option --{key}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_kv() {
        let a = parse(&["figure2", "--dataset", "epsilon", "--steps", "100", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("figure2"));
        assert_eq!(a.get_str("dataset", "rcv1"), "epsilon");
        assert_eq!(a.get::<usize>("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get::<f64>("gamma", 2.0).unwrap(), 2.0);
        assert_eq!(a.get_str("dataset", "epsilon"), "epsilon");
    }

    #[test]
    fn list_option() {
        let a = parse(&["x", "--k", "1,2,3"]);
        assert_eq!(a.get_list::<usize>("k", &[9]).unwrap(), vec![1, 2, 3]);
        let b = parse(&["x"]);
        assert_eq!(b.get_list::<usize>("k", &[9]).unwrap(), vec![9]);
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse(&["x", "--steps", "ten"]);
        assert!(a.get::<usize>("steps", 0).is_err());
    }

    #[test]
    fn unknown_option_rejected_by_finish() {
        let a = parse(&["x", "--steeps", "10"]);
        let _ = a.get::<usize>("steps", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn missing_key_prefix_is_error() {
        assert!(Args::parse(vec!["x".to_string(), "oops".to_string()]).is_err());
    }
}
