//! The end-to-end LM workload: a ~1M-parameter decoder-only transformer
//! executed entirely through the AOT artifacts.
//!
//! The exported entry point is flat —
//! `step(params: f32[P], tokens: i32[B, S+1]) → (loss, grad: f32[P])` —
//! so the Rust coordinator treats the model as an opaque gradient oracle
//! and runs **Mem-SGD on the flat gradient** exactly as it does for
//! logistic regression: compress, accumulate the residual, apply. This
//! is the full-stack composition proof: Pallas attention kernel (L1)
//! inside the JAX graph (L2) inside the PJRT executable driven by the
//! Rust coordinator (L3).
//!
//! Training data is a synthetic order-1 Markov corpus ([`markov_corpus`])
//! with ~2 bits of conditional entropy, so the loss has a long way to
//! fall from the uniform log(V) ≈ 6.24 start — a real training signal,
//! not noise fitting.

use anyhow::{Context, Result};

use super::pjrt::{PjrtRuntime, Tensor};
use crate::models::GradBackend;
use crate::util::prng::Prng;

/// Architecture metadata read from the manifest entry.
#[derive(Clone, Copy, Debug)]
pub struct TransformerMeta {
    pub param_count: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
}

/// Transformer step/loss executor over a [`PjrtRuntime`].
pub struct TransformerRuntime<'a> {
    rt: &'a mut PjrtRuntime,
    pub meta: TransformerMeta,
    init: Vec<f32>,
}

impl<'a> TransformerRuntime<'a> {
    pub fn new(rt: &'a mut PjrtRuntime) -> Result<TransformerRuntime<'a>> {
        let entry = rt.manifest.find("transformer_step")?.clone();
        let meta = TransformerMeta {
            param_count: entry.meta_usize("param_count")?,
            vocab: entry.meta_usize("vocab")?,
            seq_len: entry.meta_usize("seq_len")?,
            batch: entry.meta_usize("batch")?,
            d_model: entry.meta_usize("d_model")?,
            n_layers: entry.meta_usize("n_layers")?,
            n_heads: entry.meta_usize("n_heads")?,
        };
        let init_path = rt.manifest.dir.join(entry.meta_str("init_file")?);
        let raw = std::fs::read(&init_path)
            .with_context(|| format!("reading {}", init_path.display()))?;
        if raw.len() != meta.param_count * 4 {
            anyhow::bail!(
                "init file has {} bytes, expected {}",
                raw.len(),
                meta.param_count * 4
            );
        }
        let init: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        Ok(TransformerRuntime { rt, meta, init })
    }

    /// Deterministic PRNGKey(0) initial parameters from the artifact.
    pub fn initial_params(&self) -> Vec<f32> {
        self.init.clone()
    }

    fn token_dims(&self) -> [usize; 2] {
        [self.meta.batch, self.meta.seq_len + 1]
    }

    /// `(loss, flat gradient)` of one token batch.
    pub fn step(&mut self, params: &[f32], tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        let dims = self.token_dims();
        let outs = self.rt.execute(
            "transformer_step",
            &[
                Tensor::f32(params.to_vec(), &[self.meta.param_count]),
                Tensor::i32(tokens.to_vec(), &dims),
            ],
        )?;
        let loss = outs[0].scalar_f32()?;
        let grad = outs[1].as_f32()?.to_vec();
        Ok((loss, grad))
    }

    /// Loss only (evaluation schedule).
    pub fn loss(&mut self, params: &[f32], tokens: &[i32]) -> Result<f32> {
        let dims = self.token_dims();
        let outs = self.rt.execute(
            "transformer_loss",
            &[
                Tensor::f32(params.to_vec(), &[self.meta.param_count]),
                Tensor::i32(tokens.to_vec(), &dims),
            ],
        )?;
        outs[0].scalar_f32()
    }
}

/// A synthetic order-1 Markov language: each token has 4 preferred
/// successors taken with probability 0.9 (uniform among them), else a
/// uniform draw — conditional entropy ≈ 2.3 nats, far below the uniform
/// log V ≈ 6.24, so a model that learns the transition table improves on
/// *held-out* rollouts of the same chain.
pub struct MarkovChain {
    vocab: usize,
    succ: Vec<[u32; 4]>,
}

impl MarkovChain {
    /// Build the transition table deterministically from `seed`.
    pub fn new(vocab: usize, seed: u64) -> MarkovChain {
        let mut rng = Prng::new(seed);
        let succ = (0..vocab)
            .map(|_| {
                [
                    rng.below(vocab) as u32,
                    rng.below(vocab) as u32,
                    rng.below(vocab) as u32,
                    rng.below(vocab) as u32,
                ]
            })
            .collect();
        MarkovChain { vocab, succ }
    }

    /// Roll out `n_batches` token batches (each `batch·(seq_len+1)`
    /// row-major) with an independent rollout seed. Train and eval sets
    /// share the *table* (same language) but not the rollout.
    pub fn batches(&self, meta: &TransformerMeta, n_batches: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Prng::new(seed ^ 0x5EED_C0DE);
        let mut state = rng.below(self.vocab);
        let mut batches = Vec::with_capacity(n_batches);
        for _ in 0..n_batches {
            let mut batch = Vec::with_capacity(meta.batch * (meta.seq_len + 1));
            for _ in 0..meta.batch {
                for _ in 0..meta.seq_len + 1 {
                    batch.push(state as i32);
                    state = if rng.bernoulli(0.9) {
                        self.succ[state][rng.below(4)] as usize
                    } else {
                        rng.below(self.vocab)
                    };
                }
            }
            batches.push(batch);
        }
        batches
    }
}

/// Convenience: table and rollout from one seed (tests; prefer
/// [`MarkovChain`] when train/eval must share the language).
pub fn markov_corpus(meta: &TransformerMeta, n_batches: usize, seed: u64) -> Vec<Vec<i32>> {
    MarkovChain::new(meta.vocab, seed).batches(meta, n_batches, seed)
}

/// [`GradBackend`] adapter: Mem-SGD over the flat transformer gradient.
/// Backend "samples" are token batches; `full_loss` averages the loss
/// artifact over a held-out evaluation set.
pub struct TransformerBackend<'a> {
    pub rt: TransformerRuntime<'a>,
    train: Vec<Vec<i32>>,
    eval: Vec<Vec<i32>>,
    /// Last training loss observed by `sample_grad` (cheap progress probe).
    pub last_train_loss: f32,
}

impl<'a> TransformerBackend<'a> {
    pub fn new(
        rt: &'a mut PjrtRuntime,
        n_train_batches: usize,
        n_eval_batches: usize,
        seed: u64,
    ) -> Result<TransformerBackend<'a>> {
        let trt = TransformerRuntime::new(rt)?;
        let meta = trt.meta;
        // One language (transition table), disjoint rollouts: held-out
        // loss measures generalization, not memorization.
        let chain = MarkovChain::new(meta.vocab, seed);
        let train = chain.batches(&meta, n_train_batches, seed);
        let eval = chain.batches(&meta, n_eval_batches, seed ^ 0xEEEE_EEEE);
        Ok(TransformerBackend {
            rt: trt,
            train,
            eval,
            last_train_loss: f32::NAN,
        })
    }

    pub fn initial_params(&self) -> Vec<f32> {
        self.rt.initial_params()
    }
}

impl GradBackend for TransformerBackend<'_> {
    fn dim(&self) -> usize {
        self.rt.meta.param_count
    }

    fn n(&self) -> usize {
        self.train.len()
    }

    fn sample_grad(&mut self, x: &[f32], i: usize, out: &mut [f32]) {
        let (loss, grad) = self
            .rt
            .step(x, &self.train[i])
            .expect("transformer step failed");
        self.last_train_loss = loss;
        out.copy_from_slice(&grad);
    }

    fn full_loss(&mut self, x: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        for batch in &self.eval.clone() {
            acc += self.rt.loss(x, batch).expect("transformer loss failed") as f64;
        }
        acc / self.eval.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> TransformerMeta {
        TransformerMeta {
            param_count: 100,
            vocab: 64,
            seq_len: 16,
            batch: 2,
            d_model: 8,
            n_layers: 1,
            n_heads: 2,
        }
    }

    #[test]
    fn corpus_shapes_and_range() {
        let m = meta();
        let batches = markov_corpus(&m, 5, 1);
        assert_eq!(batches.len(), 5);
        for b in &batches {
            assert_eq!(b.len(), m.batch * (m.seq_len + 1));
            assert!(b.iter().all(|&t| t >= 0 && (t as usize) < m.vocab));
        }
    }

    #[test]
    fn corpus_is_markov_not_uniform() {
        // Successor distribution given a token must be concentrated:
        // the top-4 successors should carry ≈ 90% of the mass.
        let m = meta();
        let batches = markov_corpus(&m, 400, 2);
        let tokens: Vec<i32> = batches.concat();
        let mut counts = vec![std::collections::BTreeMap::<i32, usize>::new(); m.vocab];
        for w in tokens.windows(2) {
            *counts[w[0] as usize].entry(w[1]).or_insert(0) += 1;
        }
        // Aggregate top-4 fraction over well-observed states.
        let (mut top4, mut total) = (0usize, 0usize);
        for c in &counts {
            let n: usize = c.values().sum();
            if n < 50 {
                continue;
            }
            let mut v: Vec<usize> = c.values().copied().collect();
            v.sort_unstable_by(|a, b| b.cmp(a));
            top4 += v.iter().take(4).sum::<usize>();
            total += n;
        }
        assert!(total > 0);
        let frac = top4 as f64 / total as f64;
        assert!(frac > 0.8, "top-4 successor mass {frac}");
    }

    #[test]
    fn corpus_deterministic_in_seed() {
        let m = meta();
        assert_eq!(markov_corpus(&m, 2, 7), markov_corpus(&m, 2, 7));
        assert_ne!(markov_corpus(&m, 2, 7), markov_corpus(&m, 2, 8));
    }
}
