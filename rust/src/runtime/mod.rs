//! PJRT runtime: load the AOT HLO artifacts and execute them from Rust.
//!
//! The bridge is **HLO text**: `python/compile/aot.py` lowers each
//! Layer-2 entry point (whose matmul hot spots are the Layer-1 Pallas
//! kernels, `interpret=True`) to `artifacts/*.hlo.txt`;
//! [`pjrt::PjrtRuntime`] parses the text back
//! (`HloModuleProto::from_text_file`), compiles each module once on the
//! PJRT CPU client, and executes it with typed f32/i32 tensors. Python
//! never runs at training time — the Rust binary is self-contained once
//! `make artifacts` has produced the files.
//!
//! * [`manifest`] — machine-readable index of the artifact directory.
//! * [`pjrt`] — client, executable cache, typed execute helpers.
//! * [`logreg`] — mini-batch logistic gradients through the artifacts,
//!   as a [`crate::models::GradBackend`] (cross-checked against the
//!   native backend in the integration suite).
//! * [`transformer`] — the e2e ~1M-parameter LM: step/loss executors and
//!   a synthetic Markov-chain token corpus.

pub mod logreg;
pub mod manifest;
pub mod pjrt;
pub mod transformer;

use std::path::PathBuf;

/// Default artifact directory: `$MEMSGD_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("MEMSGD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// True if the artifact directory looks complete (manifest present).
pub fn artifacts_available() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}
