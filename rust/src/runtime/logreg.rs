//! Mini-batch logistic gradients through the AOT artifacts.
//!
//! [`PjrtLogReg`] wraps the `logreg_*` artifacts (whose matmuls are the
//! Layer-1 Pallas kernels) behind [`crate::models::GradBackend`]: the
//! "samples" of the backend are **mini-batches** — `sample_grad(x, i)`
//! returns the gradient of batch `i`'s mean loss. This is the
//! dispatch-amortized way to run SGD through PJRT (DESIGN.md §2's
//! hot-path split): one `execute` per batch instead of one per sample.
//!
//! The artifacts are lowered with `lam = 0` (pure data term); the
//! backend adds `λ·x` / `(λ/2)‖x‖²` on the Rust side, keeping a single
//! artifact valid for every regularizer strength.

use anyhow::{bail, Result};

use super::pjrt::{PjrtRuntime, Tensor};
use crate::data::{Dataset, RowView};
use crate::models::GradBackend;
use crate::util::prng::Prng;

/// Logistic-regression gradient backend over PJRT artifacts.
pub struct PjrtLogReg<'a> {
    rt: &'a mut PjrtRuntime,
    data: &'a Dataset,
    pub lam: f64,
    batch: usize,
    grad_name: String,
    loss_name: String,
    /// Precomputed batch membership: `batches[i]` are the sample indices
    /// of backend-sample `i`.
    batches: Vec<Vec<u32>>,
    // Reusable host staging buffers.
    xbuf: Vec<f32>,
    ybuf: Vec<f32>,
}

impl<'a> PjrtLogReg<'a> {
    /// Build over artifact shape `(batch, d)`; `d` must equal the
    /// dataset's dimension and an artifact `logreg_grad_b{batch}_d{d}`
    /// must exist. Batches are sampled without replacement per epoch.
    pub fn new(
        rt: &'a mut PjrtRuntime,
        data: &'a Dataset,
        batch: usize,
        lam: f64,
        seed: u64,
    ) -> Result<PjrtLogReg<'a>> {
        let d = data.d();
        let grad_name = format!("logreg_grad_b{batch}_d{d}");
        let loss_name = format!("logreg_loss_b{batch}_d{d}");
        rt.manifest.find(&grad_name)?;
        rt.manifest.find(&loss_name)?;
        if data.n() < batch {
            bail!(
                "dataset has {} samples, smaller than artifact batch {batch}",
                data.n()
            );
        }
        // Shuffle indices once and chop into batches (complete batches
        // only; the remainder is dropped like most training loops do).
        let mut idx: Vec<u32> = (0..data.n() as u32).collect();
        let mut rng = Prng::new(seed);
        rng.shuffle(&mut idx);
        let batches: Vec<Vec<u32>> = idx
            .chunks_exact(batch)
            .map(|c| c.to_vec())
            .collect();
        Ok(PjrtLogReg {
            rt,
            data,
            lam,
            batch,
            grad_name,
            loss_name,
            batches,
            xbuf: vec![0.0; batch * d],
            ybuf: vec![0.0; batch],
        })
    }

    /// Gather batch `i` into the dense staging buffers.
    fn stage(&mut self, i: usize) {
        let d = self.data.d();
        self.xbuf.iter_mut().for_each(|v| *v = 0.0);
        for (r, &sample) in self.batches[i].iter().enumerate() {
            let dst = &mut self.xbuf[r * d..(r + 1) * d];
            match self.data.row(sample as usize) {
                RowView::Dense(row) => dst.copy_from_slice(row),
                RowView::Sparse { idx, val } => {
                    for (&j, &v) in idx.iter().zip(val) {
                        dst[j as usize] = v;
                    }
                }
            }
            self.ybuf[r] = self.data.label(sample as usize);
        }
    }

    /// Number of PJRT executions so far (perf accounting).
    pub fn executions(&self) -> u64 {
        self.rt.executions
    }
}

impl GradBackend for PjrtLogReg<'_> {
    fn dim(&self) -> usize {
        self.data.d()
    }

    /// The backend's "samples" are whole mini-batches.
    fn n(&self) -> usize {
        self.batches.len()
    }

    fn sample_grad(&mut self, x: &[f32], i: usize, out: &mut [f32]) {
        let d = self.dim();
        self.stage(i);
        let inputs = [
            Tensor::f32(x.to_vec(), &[d, 1]),
            Tensor::f32(self.xbuf.clone(), &[self.batch, d]),
            Tensor::f32(self.ybuf.clone(), &[self.batch, 1]),
        ];
        let outs = self
            .rt
            .execute(&self.grad_name, &inputs)
            .expect("logreg grad artifact execution failed");
        let g = outs[0].as_f32().expect("f32 gradient");
        let lam = self.lam as f32;
        for ((o, &gi), &xi) in out.iter_mut().zip(g).zip(x) {
            *o = gi + lam * xi; // add the regularizer the artifact omits
        }
    }

    fn full_loss(&mut self, x: &[f32]) -> f64 {
        let d = self.dim();
        let mut acc = 0.0f64;
        let nb = self.batches.len();
        for i in 0..nb {
            self.stage(i);
            let inputs = [
                Tensor::f32(x.to_vec(), &[d, 1]),
                Tensor::f32(self.xbuf.clone(), &[self.batch, d]),
                Tensor::f32(self.ybuf.clone(), &[self.batch, 1]),
            ];
            let outs = self
                .rt
                .execute(&self.loss_name, &inputs)
                .expect("logreg loss artifact execution failed");
            acc += outs[0].scalar_f32().expect("scalar loss") as f64;
        }
        acc / nb as f64 + 0.5 * self.lam * crate::util::stats::l2_norm_sq(x)
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/integration_runtime.rs,
    // gated on artifacts being present. Pure batching logic is tested
    // here through a manifest-less construction failure.
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn construction_fails_without_artifact() {
        // A runtime over an empty temp dir has no manifest at all.
        let dir = std::env::temp_dir().join("memsgd_empty_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(PjrtRuntime::open(&dir).is_err());
        let _ = synthetic::epsilon_like(10, 4, 0); // keep import used
    }
}
