//! The artifact manifest: `artifacts/manifest.json`, written by
//! `python/compile/aot.py`, parsed here with the in-tree JSON module.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element type of a tensor on the artifact boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// Shape + dtype of one artifact input or output.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub dims: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One AOT-lowered entry point.
#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub meta: Json,
}

impl Entry {
    /// Typed meta accessor (`param_count`, `batch`, ...).
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta.req(key)?.as_usize()
    }

    pub fn meta_str(&self, key: &str) -> Result<String> {
        Ok(self.meta.req(key)?.as_str()?.to_string())
    }
}

/// The parsed artifact index.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "cannot read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (tests feed strings).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let doc = Json::parse(text)?;
        let format = doc.req("format")?.as_usize()?;
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let mut entries = Vec::new();
        for e in doc.req("entries")?.as_arr()? {
            let parse_io = |key: &str| -> Result<Vec<IoSpec>> {
                e.req(key)?
                    .as_arr()?
                    .iter()
                    .map(|io| {
                        Ok(IoSpec {
                            dims: io
                                .req("dims")?
                                .as_arr()?
                                .iter()
                                .map(|d| d.as_usize())
                                .collect::<Result<_>>()?,
                            dtype: Dtype::parse(io.req("dtype")?.as_str()?)?,
                        })
                    })
                    .collect()
            };
            entries.push(Entry {
                name: e.req("name")?.as_str()?.to_string(),
                file: dir.join(e.req("file")?.as_str()?),
                inputs: parse_io("inputs")?,
                outputs: parse_io("outputs")?,
                meta: e.get("meta").cloned().unwrap_or(Json::Null),
            });
        }
        Ok(Manifest { dir, entries })
    }

    /// Find an entry by exact name.
    pub fn find(&self, name: &str) -> Result<&Entry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "artifact '{name}' not in manifest (have: {})",
                    self.entries
                        .iter()
                        .map(|e| e.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// All logreg gradient entries, as `(batch, dim)` pairs.
    pub fn logreg_shapes(&self) -> Vec<(usize, usize)> {
        self.entries
            .iter()
            .filter(|e| e.name.starts_with("logreg_grad_"))
            .filter_map(|e| {
                Some((
                    e.meta.get("batch")?.as_usize().ok()?,
                    e.meta.get("dim")?.as_usize().ok()?,
                ))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "entries": [
        {"name": "logreg_grad_b4_d8", "file": "g.hlo.txt",
         "inputs": [{"dims": [8,1], "dtype": "f32"}, {"dims": [4,8], "dtype": "f32"}],
         "outputs": [{"dims": [8,1], "dtype": "f32"}],
         "meta": {"batch": 4, "dim": 8, "reg_applied": false}},
        {"name": "transformer_step", "file": "t.hlo.txt",
         "inputs": [{"dims": [100], "dtype": "f32"}, {"dims": [2,9], "dtype": "i32"}],
         "outputs": [{"dims": [], "dtype": "f32"}, {"dims": [100], "dtype": "f32"}],
         "meta": {"param_count": 100, "init_file": "init.bin"}}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.find("logreg_grad_b4_d8").unwrap();
        assert_eq!(e.inputs[1].dims, vec![4, 8]);
        assert_eq!(e.inputs[0].dtype, Dtype::F32);
        assert_eq!(e.file, PathBuf::from("/a/g.hlo.txt"));
        assert_eq!(e.meta_usize("batch").unwrap(), 4);
        let t = m.find("transformer_step").unwrap();
        assert_eq!(t.inputs[1].dtype, Dtype::I32);
        assert_eq!(t.outputs[0].dims.len(), 0); // scalar loss
        assert_eq!(t.meta_str("init_file").unwrap(), "init.bin");
    }

    #[test]
    fn logreg_shape_listing() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.logreg_shapes(), vec![(4, 8)]);
    }

    #[test]
    fn missing_entry_is_an_error_with_inventory() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        let err = format!("{:#}", m.find("nope").unwrap_err());
        assert!(err.contains("transformer_step"), "{err}");
    }

    #[test]
    fn rejects_wrong_format_version() {
        assert!(Manifest::parse(r#"{"format": 2, "entries": []}"#, PathBuf::new()).is_err());
        assert!(Manifest::parse("not json", PathBuf::new()).is_err());
    }

    #[test]
    fn io_spec_elements() {
        let io = IoSpec { dims: vec![4, 8], dtype: Dtype::F32 };
        assert_eq!(io.elements(), 32);
        let scalar = IoSpec { dims: vec![], dtype: Dtype::F32 };
        assert_eq!(scalar.elements(), 1);
    }

    #[test]
    fn real_manifest_parses_when_present() {
        if let Ok(m) = Manifest::load(crate::runtime::default_artifacts_dir()) {
            assert!(m.find("transformer_step").is_ok());
            assert!(!m.logreg_shapes().is_empty());
        }
    }
}
