//! PJRT client + executable cache + typed execution.
//!
//! Mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Every artifact is compiled exactly once
//! per process and cached; inputs are passed as literals and outputs
//! unpacked from the `return_tuple=True` 1-level tuple the AOT step
//! emits.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::manifest::{Dtype, Entry, Manifest};

/// A typed host tensor crossing the artifact boundary.
#[derive(Clone, Debug)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn f32(data: Vec<f32>, dims: &[usize]) -> Tensor {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        Tensor::F32(data, dims.to_vec())
    }

    pub fn i32(data: Vec<i32>, dims: &[usize]) -> Tensor {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        Tensor::I32(data, dims.to_vec())
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32(_, d) | Tensor::I32(_, d) => d,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Tensor::F32(..) => Dtype::F32,
            Tensor::I32(..) => Dtype::I32,
        }
    }

    /// Unwrap f32 payload.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v, _) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    /// First element as f32 scalar.
    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        v.first().copied().context("empty tensor")
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32(v, _) => xla::Literal::vec1(v),
            Tensor::I32(v, _) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }
}

/// The runtime: one PJRT CPU client, one compiled executable per artifact.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cumulative host→device→host execution count (metrics / perf logs).
    pub executions: u64,
}

impl PjrtRuntime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<PjrtRuntime> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime {
            client,
            manifest,
            cache: HashMap::new(),
            executions: 0,
        })
    }

    /// Open the default directory (`$MEMSGD_ARTIFACTS` / `./artifacts`).
    pub fn open_default() -> Result<PjrtRuntime> {
        Self::open(super::default_artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and return the executable for `name`.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self.manifest.find(name)?.clone();
            let exe = self.compile_entry(&entry)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    fn compile_entry(&self, entry: &Entry) -> Result<xla::PjRtLoadedExecutable> {
        let path: &PathBuf = &entry.file;
        let path_str = path
            .to_str()
            .with_context(|| format!("non-utf8 path {}", path.display()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{}'", entry.name))
    }

    /// Force-compile an artifact (warmup; keeps first-step latency out of
    /// measured loops).
    pub fn warmup(&mut self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    /// Execute artifact `name` with typed inputs; returns the unpacked
    /// output tuple. Input shapes/dtypes are validated against the
    /// manifest before touching PJRT.
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let entry = self.manifest.find(name)?;
        if inputs.len() != entry.inputs.len() {
            bail!(
                "artifact '{name}': expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
            if t.dims() != spec.dims.as_slice() || t.dtype() != spec.dtype {
                bail!(
                    "artifact '{name}' input {i}: expected {:?}{:?}, got {:?}{:?}",
                    spec.dtype,
                    spec.dims,
                    t.dtype(),
                    t.dims()
                );
            }
        }
        let out_specs = entry.outputs.clone();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        self.executions += 1;
        // aot.py lowers with return_tuple=True → always a 1-level tuple.
        let parts = result.to_tuple()?;
        if parts.len() != out_specs.len() {
            bail!(
                "artifact '{name}': manifest promises {} outputs, got {}",
                out_specs.len(),
                parts.len()
            );
        }
        parts
            .into_iter()
            .zip(&out_specs)
            .map(|(lit, spec)| {
                Ok(match spec.dtype {
                    Dtype::F32 => Tensor::F32(lit.to_vec::<f32>()?, spec.dims.clone()),
                    Dtype::I32 => Tensor::I32(lit.to_vec::<i32>()?, spec.dims.clone()),
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.dims(), &[2, 2]);
        assert_eq!(t.dtype(), Dtype::F32);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        let s = Tensor::f32(vec![7.5], &[]);
        assert_eq!(s.scalar_f32().unwrap(), 7.5);
        assert!(Tensor::i32(vec![1], &[1]).as_f32().is_err());
    }

    #[test]
    #[should_panic]
    fn tensor_rejects_wrong_element_count() {
        Tensor::f32(vec![1.0; 3], &[2, 2]);
    }

    // Full execute-path coverage lives in rust/tests/integration_runtime.rs
    // (requires `make artifacts`).
}
