//! Property-based invariant suite (util::check harness, proptest-style):
//! randomized shapes, seeds and operators against the algebraic
//! invariants the paper's analysis rests on.

use memsgd::compress::{self, Compressor, SparseVec, Update};
use memsgd::data::synthetic;
use memsgd::models::{GradBackend, LogisticModel};
use memsgd::optim::{MemSgd, WeightedAverage};
use memsgd::util::check::{check, ensure, ensure_allclose, ensure_close};
use memsgd::util::prng::Prng;
use memsgd::util::stats;

fn random_vec(rng: &mut Prng, d: usize) -> Vec<f32> {
    (0..d).map(|_| rng.normal_f32() * 3.0).collect()
}

/// Definition 2.1 for every sparsifying operator, on random inputs.
#[test]
fn prop_contraction_property_all_sparsifiers() {
    check("contraction", 300, |rng| {
        let d = 1 + rng.below(256);
        let k = 1 + rng.below(d);
        let spec = match rng.below(3) {
            0 => format!("top_k:{k}"),
            1 => format!("rand_k:{k}"),
            _ => "random_p:1.0".to_string(), // k = 1 contraction
        };
        let mut comp = compress::from_spec(&spec).unwrap();
        let x = random_vec(rng, d);
        let mut out = Update::new_sparse(d);
        comp.compress(&x, rng, &mut out);
        let dense = out.to_dense(d);
        let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
        let kk = comp.contraction_k(d).unwrap();
        // top-k: pointwise; rand-k/random-p: the pointwise bound with
        // k' = actual nnz >= bound with k in expectation — check the
        // crude pointwise bound ||resid||^2 <= ||x||^2 plus the exact
        // top-k bound when deterministic.
        let x2 = stats::l2_norm_sq(&x);
        ensure(stats::l2_norm_sq(&resid) <= x2 + 1e-6, "residual grew")?;
        if spec.starts_with("top_k") {
            let bound = (1.0 - kk / d as f64) * x2;
            ensure(
                stats::l2_norm_sq(&resid) <= bound + 1e-6,
                format!("top-k contraction violated: d={d} k={k}"),
            )?;
        }
        Ok(())
    });
}

/// Definition 2.1 against `contraction_k()`'s **claimed** value, for
/// every compressor in the crate. The deterministic operators (top-k,
/// block-top-k, sign, threshold, identity) must satisfy the inequality
/// pointwise on every input; QSGD claims `None` and is asserted to.
#[test]
fn prop_contraction_matches_claimed_k_pointwise_for_deterministic_ops() {
    check("contraction-claimed-pointwise", 200, |rng| {
        let d = 1 + rng.below(200);
        let k = 1 + rng.below(d);
        let tau = 0.05 + 0.9 * rng.f64();
        let specs = [
            format!("top_k:{k}"),
            format!("block_top_k:{k}"),
            "sign".to_string(),
            format!("threshold:{tau}"),
            "identity".to_string(),
        ];
        let x = random_vec(rng, d);
        let x2 = stats::l2_norm_sq(&x);
        for spec in &specs {
            let mut comp = compress::from_spec(spec).unwrap();
            let mut out = Update::new_sparse(d);
            comp.compress(&x, rng, &mut out);
            let dense = out.to_dense(d);
            let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
            let kk = comp
                .contraction_k(d)
                .ok_or_else(|| format!("{spec} claims no contraction"))?;
            let bound = (1.0 - kk / d as f64) * x2;
            ensure(
                stats::l2_norm_sq(&resid) <= bound + 1e-5 + 1e-5 * x2,
                format!("{spec}: claimed k={kk} violated at d={d}"),
            )?;
        }
        ensure(
            compress::from_spec("qsgd:16").unwrap().contraction_k(d).is_none(),
            "qsgd must claim no contraction parameter",
        )
    });
}

/// The randomized sparsifiers (rand-k, random-p) satisfy Definition 2.1
/// **in expectation** — with equality (Lemma A.1) — so the residual
/// norm averaged over many operator draws must match
/// `(1 − k/d)·‖x‖²` for `contraction_k()`'s claimed `k`.
#[test]
fn prop_contraction_matches_claimed_k_in_expectation_for_randomized_ops() {
    check("contraction-claimed-expectation", 12, |rng| {
        let d = 4 + rng.below(40);
        let k = 1 + rng.below(d);
        let p = 0.1 + 0.9 * rng.f64();
        let x = random_vec(rng, d);
        let x2 = stats::l2_norm_sq(&x);
        let trials = 3_000;
        for spec in [format!("rand_k:{k}"), format!("random_p:{p}")] {
            let mut comp = compress::from_spec(&spec).unwrap();
            let kk = comp.contraction_k(d).unwrap();
            let mut out = Update::new_sparse(d);
            let mut acc = 0.0f64;
            for _ in 0..trials {
                comp.compress(&x, rng, &mut out);
                let dense = out.to_dense(d);
                let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
                acc += stats::l2_norm_sq(&resid);
            }
            let mean = acc / trials as f64;
            let expected = (1.0 - kk / d as f64) * x2;
            ensure_close(
                mean,
                expected,
                0.12,
                0.03 * x2 + 1e-9,
                &format!("{spec} at d={d} (claimed k={kk})"),
            )?;
        }
        Ok(())
    });
}

/// Compressed output values are always a subset of the input values
/// (sparsifiers never invent values).
#[test]
fn prop_sparsifiers_copy_not_transform() {
    check("copy-not-transform", 200, |rng| {
        let d = 1 + rng.below(128);
        let k = 1 + rng.below(d);
        let spec = if rng.bernoulli(0.5) {
            format!("top_k:{k}")
        } else {
            format!("rand_k:{k}")
        };
        let mut comp = compress::from_spec(&spec).unwrap();
        let x = random_vec(rng, d);
        let mut out = Update::new_sparse(d);
        comp.compress(&x, rng, &mut out);
        if let Update::Sparse(s) = &out {
            for (&i, &v) in s.idx.iter().zip(&s.val) {
                ensure(
                    v == x[i as usize],
                    format!("{spec}: value at {i} altered"),
                )?;
            }
            // no duplicate indices
            let mut idx = s.idx.clone();
            idx.sort_unstable();
            idx.dedup();
            ensure(idx.len() == s.nnz(), "duplicate indices")?;
        }
        Ok(())
    });
}

/// Mem-SGD conservation: x_t − m_t replays the uncompressed trajectory
/// for arbitrary operators, stepsizes, and gradient sequences.
#[test]
fn prop_memsgd_conservation() {
    check("memsgd-conservation", 60, |rng| {
        let d = 2 + rng.below(64);
        let k = 1 + rng.below(d);
        let spec = match rng.below(3) {
            0 => format!("top_k:{k}"),
            1 => format!("rand_k:{k}"),
            _ => "random_p:0.5".to_string(),
        };
        let mut opt = MemSgd::new(random_vec(rng, d), compress::from_spec(&spec).unwrap());
        let mut virt = opt.x.clone();
        for t in 0..100 {
            let g = random_vec(rng, d);
            let eta = 0.01 + rng.f64();
            for (v, &gj) in virt.iter_mut().zip(&g) {
                *v -= eta as f32 * gj;
            }
            opt.step(&g, eta, rng);
            if t % 25 == 24 {
                ensure_allclose(&opt.virtual_iterate(), &virt, 2e-3, 2e-3, &spec)?;
            }
        }
        Ok(())
    });
}

/// QSGD unbiasedness on random vectors (mean over repeats ≈ identity).
#[test]
fn prop_qsgd_unbiased() {
    check("qsgd-unbiased", 20, |rng| {
        let d = 2 + rng.below(32);
        let levels = 1u32 << (1 + rng.below(6)); // 2..64
        let x = random_vec(rng, d);
        let mut comp = compress::Qsgd::new(levels);
        let mut out = Update::new_dense(d);
        let trials = 4_000;
        let mut acc = vec![0.0f64; d];
        for _ in 0..trials {
            comp.compress(&x, rng, &mut out);
            if let Update::Dense(g) = &out {
                for (a, &v) in acc.iter_mut().zip(g) {
                    *a += v as f64;
                }
            }
        }
        let norm = stats::l2_norm(&x);
        for (j, (&xj, &aj)) in x.iter().zip(&acc).enumerate() {
            let mean = aj / trials as f64;
            // standard error of a bounded quantizer scales with ||x||
            ensure_close(
                mean,
                xj as f64,
                0.0,
                0.12 * norm,
                &format!("coord {j} of d={d}, s={levels}"),
            )?;
        }
        Ok(())
    });
}

/// Streaming weighted average == batch weighted average.
#[test]
fn prop_weighted_average_streaming() {
    check("weighted-average", 100, |rng| {
        let d = 1 + rng.below(32);
        let t_total = 1 + rng.below(100);
        let shift = 1.0 + rng.f64() * 500.0;
        let iterates: Vec<Vec<f32>> = (0..t_total).map(|_| random_vec(rng, d)).collect();
        let mut avg = WeightedAverage::new(d, shift);
        for x in &iterates {
            avg.update(x);
        }
        let mut acc = vec![0.0f64; d];
        let mut sw = 0.0;
        for (t, x) in iterates.iter().enumerate() {
            let w = (shift + t as f64) * (shift + t as f64);
            sw += w;
            for (a, &xi) in acc.iter_mut().zip(x) {
                *a += w * xi as f64;
            }
        }
        let want: Vec<f32> = acc.iter().map(|a| (a / sw) as f32).collect();
        ensure_allclose(&avg.average(), &want, 1e-4, 1e-4, "avg")
    });
}

/// SparseVec apply/undo round-trips and norm bookkeeping.
#[test]
fn prop_sparsevec_algebra() {
    check("sparsevec-algebra", 200, |rng| {
        let d = 1 + rng.below(100);
        let nnz = rng.below(d + 1);
        let mut idxs: Vec<u32> = Vec::new();
        rng.sample_distinct(d, nnz, &mut idxs);
        let mut sv = SparseVec::new(d);
        for &i in &idxs {
            sv.push(i, rng.normal_f32());
        }
        let mut x = random_vec(rng, d);
        let orig = x.clone();
        sv.sub_from(&mut x);
        sv.add_to(&mut x);
        ensure_allclose(&x, &orig, 1e-5, 1e-5, "sub/add round trip")?;
        let dense = sv.to_dense();
        ensure_close(sv.norm_sq(), stats::l2_norm_sq(&dense), 1e-9, 1e-9, "norms")
    });
}

/// Logistic gradients match central finite differences on random data
/// (dense and sparse feature paths).
#[test]
fn prop_logistic_grad_finite_difference() {
    check("logistic-fd", 30, |rng| {
        let n = 20 + rng.below(60);
        let d = 2 + rng.below(12);
        let data = if rng.bernoulli(0.5) {
            synthetic::epsilon_like(n, d, rng.next_u64())
        } else {
            synthetic::rcv1_like(n, d, 0.5, rng.next_u64())
        };
        let lam = rng.f64() * 0.3;
        let mut model = LogisticModel::new(&data, lam);
        let x: Vec<f32> = (0..d).map(|_| 0.3 * rng.normal_f32()).collect();
        let mut grad = vec![0.0f32; d];
        model.full_grad(&x, &mut grad);
        let j = rng.below(d);
        let eps = 1e-3f32;
        let mut xp = x.clone();
        xp[j] += eps;
        let mut xm = x.clone();
        xm[j] -= eps;
        let fd = (model.full_loss(&xp) - model.full_loss(&xm)) / (2.0 * eps as f64);
        ensure_close(fd, grad[j] as f64, 5e-2, 5e-3, &format!("coord {j}"))
    });
}

/// Bit accounting: every sparsifier pays exactly nnz·(32 + ceil(log2 d)).
#[test]
fn prop_bit_accounting_exact() {
    check("bit-accounting", 200, |rng| {
        let d = 2 + rng.below(60_000);
        let k = 1 + rng.below(20.min(d));
        let mut comp = compress::from_spec(&format!("top_k:{k}")).unwrap();
        let x = random_vec(rng, d.min(4_096)); // cap alloc; use real d for bits
        let mut out = Update::new_sparse(x.len());
        let bits = comp.compress(&x, rng, &mut out);
        let nnz = out.nnz() as u64;
        let idx_bits = memsgd::compress::sparse::index_bits(x.len());
        ensure(
            bits == nnz * (32 + idx_bits),
            format!("bits {bits} != {nnz}*(32+{idx_bits})"),
        )
    });
}

/// Wire-decoder totality: `decode_sparse` / `decode_qsgd` /
/// `decode_payload` / `decode_msg` on arbitrary byte soup must return
/// descriptive errors — never panic, never do work proportional to a
/// hostile `nnz`/index/level field (the count guards reject anything
/// beyond the dimension before allocating).
#[test]
fn prop_wire_decoders_are_total_on_arbitrary_bytes() {
    use memsgd::compress::elias::{decode_payload, decode_qsgd, decode_sparse, BitReader};
    use memsgd::coordinator::transport::decode_msg;
    check("wire-decoder-totality", 1500, |rng| {
        let dim = 1 + rng.below(3_000);
        let len = rng.below(160);
        let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        // Any of these may Ok or Err; none may panic. On Ok, the
        // decoded structure must satisfy its own invariants.
        if let Ok(s) = decode_sparse(&mut BitReader::new(&bytes), dim) {
            ensure(s.nnz() <= dim, "nnz beyond dim")?;
            ensure(
                s.idx.windows(2).all(|w| w[0] < w[1]),
                "decoded indices not strictly increasing",
            )?;
            ensure(s.idx.iter().all(|&i| (i as usize) < dim), "index out of range")?;
        }
        if let Ok((_, levels)) = decode_qsgd(&mut BitReader::new(&bytes), dim) {
            ensure(levels.len() == dim, "levels length")?;
        }
        if let Ok(u) = decode_payload(&mut BitReader::new(&bytes), dim) {
            ensure(u.to_dense(dim).len() == dim, "payload dimension")?;
        }
        let _ = decode_msg(&bytes, dim);
        Ok(())
    });
}

/// Truncating a valid payload below its content length must error (and
/// flipping any single bit must at worst error — never panic).
#[test]
fn prop_wire_decoders_survive_truncation_and_corruption() {
    use memsgd::compress::elias::{decode_sparse, encode_sparse, BitReader, BitWriter};
    check("wire-decoder-truncation", 250, |rng| {
        let dim = 2 + rng.below(500);
        let nnz = 1 + rng.below(dim.min(24));
        let mut idx = Vec::new();
        rng.sample_distinct(dim, nnz, &mut idx);
        let mut s = SparseVec::new(dim);
        for &i in &idx {
            s.push(i, rng.normal_f32());
        }
        let mut w = BitWriter::new();
        let content_bits = encode_sparse(&s, &mut w);
        let bytes = w.as_bytes();
        // A prefix strictly shorter than the content must fail cleanly.
        let cut_bytes = ((content_bits - 1) / 8) as usize;
        ensure(
            decode_sparse(&mut BitReader::new(&bytes[..cut_bytes]), dim).is_err(),
            "truncated stream decoded",
        )?;
        // A random single-bit flip: Ok or Err, never panic; Ok results
        // keep the structural invariants.
        let mut corrupt = bytes.to_vec();
        let flip = rng.below(corrupt.len() * 8);
        corrupt[flip / 8] ^= 1 << (7 - (flip % 8));
        if let Ok(back) = decode_sparse(&mut BitReader::new(&corrupt), dim) {
            ensure(back.nnz() <= dim, "corrupt decode broke the nnz bound")?;
            ensure(
                back.idx.iter().all(|&i| (i as usize) < dim),
                "corrupt decode broke the index bound",
            )?;
        }
        Ok(())
    });
}

/// Every compressor's framed payload round-trips bit-exactly at random
/// shapes — the codec-level half of the wire-equivalence guarantee
/// (`tests/wire_protocol.rs` pins the engine-level half).
#[test]
fn prop_payload_roundtrip_every_compressor() {
    use memsgd::compress::elias::{decode_payload, BitReader, BitWriter};
    check("payload-roundtrip", 200, |rng| {
        let d = 1 + rng.below(300);
        let k = 1 + rng.below(d);
        let specs = [
            "identity".to_string(),
            format!("top_k:{k}"),
            format!("rand_k:{k}"),
            "random_p:0.7".to_string(),
            format!("block_top_k:{k}"),
            "sign".to_string(),
            "threshold:0.3".to_string(),
            "qsgd:8".to_string(),
            format!("qsgd:8(top_k:{k})"),
            format!("adaptive:{k}"),
        ];
        let spec = &specs[rng.below(specs.len())];
        let mut comp = compress::from_spec(spec).unwrap();
        let x = random_vec(rng, d);
        let mut out = Update::new_sparse(d);
        comp.compress(&x, rng, &mut out);
        let mut w = BitWriter::new();
        let bits = comp.encode_payload(&out, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_payload(&mut r, d)
            .map_err(|e| format!("{spec} d={d}: decode failed: {e:#}"))?;
        ensure(r.consumed() == bits, format!("{spec}: consumed != produced"))?;
        let want: Vec<u32> = out.to_dense(d).iter().map(|v| v.to_bits()).collect();
        let got: Vec<u32> = back.to_dense(d).iter().map(|v| v.to_bits()).collect();
        ensure(got == want, format!("{spec} d={d}: payload not bit-exact"))
    });
}

/// Composed contraction: `contraction_k()` must be exactly the Qsparse
/// Lemma 1 product of the parts, and the composed operator's residual
/// second moment must respect the claimed bound in expectation
/// (pointwise inner top-k selection, randomized outer quantizer).
#[test]
fn prop_composed_contraction_matches_product_form_in_expectation() {
    check("composed-contraction", 10, |rng| {
        let d = 8 + rng.below(48);
        let k = 1 + rng.below(d.min(12));
        let s = 1u32 << (2 + rng.below(5)); // 4..64 levels
        let spec = format!("qsgd:{s}(top_k:{k})");
        let mut comp = compress::from_spec(&spec).unwrap();
        let inner_k = compress::from_spec(&format!("top_k:{k}"))
            .unwrap()
            .contraction_k(d)
            .unwrap();
        let claimed = comp.contraction_k(d);
        ensure(
            claimed == compress::composed_contraction(s, inner_k, d),
            format!("{spec}: claimed k is not the Lemma 1 product"),
        )?;
        let Some(kk) = claimed else { return Ok(()) };
        let x = random_vec(rng, d);
        let x2 = stats::l2_norm_sq(&x);
        let trials = 2_000;
        let mut acc = 0.0f64;
        let mut out = Update::new_sparse(d);
        for _ in 0..trials {
            comp.compress(&x, rng, &mut out);
            let dense = out.to_dense(d);
            let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
            acc += stats::l2_norm_sq(&resid);
        }
        let mean = acc / trials as f64;
        // Lemma 1 gives an upper bound, not an equality: slack on the
        // bound side only, for quantizer sampling noise.
        let bound = (1.0 - kk / d as f64) * x2;
        ensure(
            mean <= bound * 1.05 + 0.02 * x2 + 1e-9,
            format!("{spec} d={d}: E residual {mean} > claimed bound {bound}"),
        )
    });
}

/// Adaptive sparsification: keep probabilities realize the budget, the
/// estimator is unbiased, the expected kept count is `contraction_k()`'s
/// reported budget, and the residual second moment matches the closed
/// form `Σ x_i²·(1/p_i − 1)` — the in-expectation semantics documented
/// in `compress/adaptive.rs` (the Definition 2.1 inequality itself is
/// not claimed).
#[test]
fn prop_adaptive_unbiased_with_closed_form_variance() {
    check("adaptive-expectation", 8, |rng| {
        let d = 4 + rng.below(24);
        let budget = 1 + rng.below(d.min(8));
        let x = random_vec(rng, d);
        let x2 = stats::l2_norm_sq(&x);
        let mut a = compress::AdaptiveSparse::new(budget);
        let mut p = Vec::new();
        a.keep_probabilities(&x, &mut p);
        let nz = x.iter().filter(|&&v| v != 0.0).count();
        let psum: f64 = p.iter().sum();
        ensure_close(
            psum,
            budget.min(nz) as f64,
            1e-9,
            1e-9,
            "sum of keep probabilities",
        )?;
        ensure(
            a.contraction_k(d) == Some(budget.min(d) as f64),
            "contraction_k must report the in-expectation budget",
        )?;
        let trials = 5_000;
        let mut acc = vec![0.0f64; d];
        let mut nnz_acc = 0usize;
        let mut var_acc = 0.0f64;
        let mut out = Update::new_sparse(d);
        for _ in 0..trials {
            a.compress(&x, rng, &mut out);
            nnz_acc += out.nnz();
            let dense = out.to_dense(d);
            for (s, &v) in acc.iter_mut().zip(&dense) {
                *s += v as f64;
            }
            let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
            var_acc += stats::l2_norm_sq(&resid);
        }
        let norm = stats::l2_norm(&x);
        for (j, (&xj, &aj)) in x.iter().zip(&acc).enumerate() {
            ensure_close(
                aj / trials as f64,
                xj as f64,
                0.0,
                0.15 * norm + 1e-6,
                &format!("unbiasedness at coord {j} of d={d}"),
            )?;
        }
        ensure_close(
            nnz_acc as f64 / trials as f64,
            budget.min(nz) as f64,
            0.05,
            0.15,
            "expected kept count",
        )?;
        let want: f64 = x
            .iter()
            .zip(&p)
            .filter(|(_, &pi)| pi > 0.0)
            .map(|(&xi, &pi)| (xi as f64).powi(2) * (1.0 / pi - 1.0))
            .sum();
        ensure_close(
            var_acc / trials as f64,
            want,
            0.2,
            0.05 * x2 + 1e-9,
            "closed-form variance",
        )
    });
}

/// Composed-payload robustness: truncating a valid `TAG_COMPOSED` frame
/// at any byte cut must error cleanly, and a single-bit flip must at
/// worst error — an `Ok` keeps the structural invariants. Arbitrary-
/// byte totality for the tag is covered by
/// `prop_wire_decoders_are_total_on_arbitrary_bytes` above.
#[test]
fn prop_composed_payload_survives_truncation_and_corruption() {
    use memsgd::compress::elias::{decode_payload, BitReader, BitWriter};
    check("composed-payload-robustness", 150, |rng| {
        let d = 2 + rng.below(400);
        let k = 1 + rng.below(d.min(16));
        let mut comp = compress::from_spec(&format!("qsgd:8(top_k:{k})")).unwrap();
        let x = random_vec(rng, d);
        let mut out = Update::new_sparse(d);
        comp.compress(&x, rng, &mut out);
        let mut w = BitWriter::new();
        let bits = comp.encode_payload(&out, &mut w);
        let bytes = w.as_bytes();
        // A prefix with fewer than the content bits must fail cleanly.
        let cut = rng.below(((bits - 1) / 8) as usize + 1);
        ensure(
            decode_payload(&mut BitReader::new(&bytes[..cut]), d).is_err(),
            format!("truncated composed frame decoded at byte {cut}"),
        )?;
        // A random single-bit flip inside the content region.
        let mut corrupt = bytes.to_vec();
        let flip = rng.below(bits as usize);
        corrupt[flip / 8] ^= 1 << (7 - (flip % 8));
        if let Ok(u) = decode_payload(&mut BitReader::new(&corrupt), d) {
            ensure(u.to_dense(d).len() == d, "corrupt decode broke the dimension")?;
            if let Update::Sparse(s) = &u {
                ensure(s.nnz() <= d, "corrupt decode broke the nnz bound")?;
                ensure(
                    s.idx.iter().all(|&i| (i as usize) < d),
                    "corrupt decode broke the index bound",
                )?;
            }
        }
        Ok(())
    });
}

/// TCP length-framing totality: the frame reader must survive hostile
/// byte streams the in-process loopback could never produce — an
/// oversized declared length is refused **before allocation**, a
/// mid-frame EOF errors descriptively at any cut point, and a slow peer
/// trickling one byte per `read` still assembles the frame intact.
#[test]
fn prop_tcp_framing_survives_hostile_and_trickling_streams() {
    use memsgd::coordinator::net::{read_frame, write_frame};
    use std::io::Read;

    /// Yields its buffer one byte per `read` call — the slowest
    /// conforming peer.
    struct Trickle<'a> {
        data: &'a [u8],
        pos: usize,
    }
    impl Read for Trickle<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.data.len() || buf.is_empty() {
                return Ok(0);
            }
            buf[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    check("tcp-framing", 400, |rng| {
        let cap = 1 + rng.below(512);
        // 1) A valid frame round-trips, even one byte at a time.
        let len = rng.below(cap + 1);
        let payload: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let mut wire = Vec::new();
        write_frame(&mut wire, &payload).map_err(|e| format!("write: {e:#}"))?;
        let back = read_frame(&mut Trickle { data: &wire, pos: 0 }, cap)
            .map_err(|e| format!("trickle read: {e:#}"))?;
        ensure(back == payload, "trickled frame corrupted")?;

        // 2) A length prefix over the cap is refused before any
        //    allocation, whatever bytes follow it.
        let over = cap as u32 + 1 + rng.below(1 << 16) as u32;
        let mut hostile = over.to_be_bytes().to_vec();
        hostile.extend_from_slice(&payload);
        let err = read_frame(&mut Trickle { data: &hostile, pos: 0 }, cap).unwrap_err();
        ensure(
            format!("{err:#}").contains("max_frame_bytes"),
            format!("oversized prefix not refused by name: {err:#}"),
        )?;

        // 3) Mid-frame EOF: every strict prefix of a frame errors with
        //    a connection-closed diagnosis, never a hang or panic.
        let cut = rng.below(wire.len());
        let err = read_frame(&mut Trickle { data: &wire[..cut], pos: 0 }, cap).unwrap_err();
        ensure(
            format!("{err:#}").contains("closed"),
            format!("mid-frame EOF at byte {cut} not a close error: {err:#}"),
        )?;
        Ok(())
    });
}
