//! Runtime integration: the AOT artifacts must load, compile, execute,
//! and agree numerically with the native Rust backends. This is the
//! cross-layer correctness proof: Pallas kernel (L1) == JAX graph (L2)
//! == HLO artifact through PJRT (runtime) == native Rust (L3 reference).
//!
//! All tests skip gracefully when `make artifacts` has not run.

use memsgd::data::{synthetic, Dataset};
use memsgd::models::{GradBackend, LogisticModel};
use memsgd::runtime::logreg::PjrtLogReg;
use memsgd::runtime::pjrt::{PjrtRuntime, Tensor};
use memsgd::runtime::transformer::{markov_corpus, TransformerBackend, TransformerRuntime};
use memsgd::util::prng::Prng;
use memsgd::util::stats;

fn runtime() -> Option<PjrtRuntime> {
    if !memsgd::runtime::artifacts_available() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(PjrtRuntime::open_default().expect("open runtime"))
}

/// Native full-batch logistic gradient with lam = 0 over given rows.
fn native_batch_grad(data: &Dataset, rows: &[usize], w: &[f32]) -> Vec<f32> {
    let mut model = LogisticModel::new(data, 0.0);
    let d = data.d();
    let mut acc = vec![0.0f32; d];
    let mut tmp = vec![0.0f32; d];
    for &i in rows {
        model.sample_grad(w, i, &mut tmp);
        for (a, &t) in acc.iter_mut().zip(&tmp) {
            *a += t / rows.len() as f32;
        }
    }
    acc
}

fn stage(data: &Dataset, b: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let mut xbuf = vec![0.0f32; b * d];
    let mut ybuf = vec![0.0f32; b];
    for i in 0..b {
        if let memsgd::data::RowView::Dense(row) = data.row(i) {
            xbuf[i * d..(i + 1) * d].copy_from_slice(row);
        }
        ybuf[i] = data.label(i);
    }
    (xbuf, ybuf)
}

#[test]
fn logreg_grad_artifact_matches_native() {
    let Some(mut rt) = runtime() else { return };
    let (b, d) = (64usize, 512usize);
    let data = synthetic::epsilon_like(b, d, 42);
    let mut rng = Prng::new(7);
    let w: Vec<f32> = (0..d).map(|_| 0.1 * rng.normal_f32()).collect();
    let (xbuf, ybuf) = stage(&data, b, d);
    let outs = rt
        .execute(
            "logreg_grad_b64_d512",
            &[
                Tensor::f32(w.clone(), &[d, 1]),
                Tensor::f32(xbuf, &[b, d]),
                Tensor::f32(ybuf, &[b, 1]),
            ],
        )
        .expect("execute");
    let got = outs[0].as_f32().unwrap();
    let want = native_batch_grad(&data, &(0..b).collect::<Vec<_>>(), &w);
    let err = stats::rel_l2_err(got, &want);
    assert!(err < 1e-4, "pjrt vs native rel err {err}");
}

#[test]
fn logreg_wide_artifact_matches_native() {
    // The paper-width artifact (d = 2000, batch 256).
    let Some(mut rt) = runtime() else { return };
    let (b, d) = (256usize, 2000usize);
    let data = synthetic::epsilon_like(b, d, 8);
    let w = vec![0.02f32; d];
    let (xbuf, ybuf) = stage(&data, b, d);
    let outs = rt
        .execute(
            "logreg_grad_b256_d2000",
            &[
                Tensor::f32(w.clone(), &[d, 1]),
                Tensor::f32(xbuf, &[b, d]),
                Tensor::f32(ybuf, &[b, 1]),
            ],
        )
        .expect("execute");
    let want = native_batch_grad(&data, &(0..b).collect::<Vec<_>>(), &w);
    let err = stats::rel_l2_err(outs[0].as_f32().unwrap(), &want);
    assert!(err < 1e-4, "rel err {err}");
}

#[test]
fn logreg_loss_and_grad_artifacts_consistent() {
    let Some(mut rt) = runtime() else { return };
    let (b, d) = (64usize, 512usize);
    let data = synthetic::epsilon_like(b, d, 1);
    let (xbuf, ybuf) = stage(&data, b, d);
    let inputs = [
        Tensor::f32(vec![0.0; d], &[d, 1]),
        Tensor::f32(xbuf, &[b, d]),
        Tensor::f32(ybuf, &[b, 1]),
    ];
    let lg = rt.execute("logreg_loss_grad_b64_d512", &inputs).unwrap();
    let l = rt.execute("logreg_loss_b64_d512", &inputs).unwrap();
    let g = rt.execute("logreg_grad_b64_d512", &inputs).unwrap();
    let fused_loss = lg[0].scalar_f32().unwrap();
    assert!((fused_loss - l[0].scalar_f32().unwrap()).abs() < 1e-6);
    // At w = 0, loss = log 2 exactly.
    assert!((fused_loss - std::f32::consts::LN_2).abs() < 1e-5);
    let err = stats::rel_l2_err(lg[1].as_f32().unwrap(), g[0].as_f32().unwrap());
    assert!(err < 1e-6, "fused vs standalone grad err {err}");
}

#[test]
fn execute_validates_shapes_and_names() {
    let Some(mut rt) = runtime() else { return };
    assert!(rt.execute("logreg_grad_b64_d512", &[]).is_err());
    let bad = [
        Tensor::f32(vec![0.0; 10], &[10, 1]),
        Tensor::f32(vec![0.0; 10], &[1, 10]),
        Tensor::f32(vec![0.0; 1], &[1, 1]),
    ];
    assert!(rt.execute("logreg_grad_b64_d512", &bad).is_err());
    assert!(rt.execute("nope", &[]).is_err());
}

#[test]
fn pjrt_logreg_backend_matches_native() {
    let Some(mut rt) = runtime() else { return };
    let (b, d) = (64usize, 512usize);
    let data = synthetic::epsilon_like(4 * b, d, 3); // 4 complete batches
    let lam = 0.01;
    let mut backend = PjrtLogReg::new(&mut rt, &data, b, lam, 9).unwrap();
    assert_eq!(backend.n(), 4);
    assert_eq!(backend.dim(), d);

    let mut rng = Prng::new(5);
    let w: Vec<f32> = (0..d).map(|_| 0.05 * rng.normal_f32()).collect();
    let mut out = vec![0.0f32; d];
    backend.sample_grad(&w, 2, &mut out);
    assert!(out.iter().all(|v| v.is_finite()));
    let pjrt_loss = backend.full_loss(&w);
    let mut native = LogisticModel::new(&data, lam);
    let native_loss = native.full_loss(&w);
    assert!(
        (pjrt_loss - native_loss).abs() < 1e-4,
        "pjrt {pjrt_loss} vs native {native_loss}"
    );
}

#[test]
fn transformer_step_matches_finite_difference() {
    let Some(mut rt) = runtime() else { return };
    let mut trt = TransformerRuntime::new(&mut rt).expect("transformer runtime");
    let meta = trt.meta;
    assert!(meta.param_count > 500_000, "expected ~1M params");
    let params = trt.initial_params();
    let tokens = markov_corpus(&meta, 1, 11).remove(0);

    let (loss, grad) = trt.step(&params, &tokens).expect("step");
    let uniform = (meta.vocab as f32).ln();
    assert!(
        (loss - uniform).abs() < 0.5,
        "init loss {loss} vs log V {uniform}"
    );
    assert_eq!(grad.len(), meta.param_count);
    assert!(grad.iter().all(|g| g.is_finite()));
    assert!(stats::l2_norm(&grad) > 1e-3, "gradient unexpectedly zero");

    // Directional finite difference through the loss artifact.
    let mut rng = Prng::new(13);
    let mut u: Vec<f32> = (0..meta.param_count).map(|_| rng.normal_f32()).collect();
    let un = stats::l2_norm(&u) as f32;
    u.iter_mut().for_each(|v| *v /= un);
    let eps = 5e-3f32;
    let pp: Vec<f32> = params.iter().zip(&u).map(|(p, du)| p + eps * du).collect();
    let pm: Vec<f32> = params.iter().zip(&u).map(|(p, du)| p - eps * du).collect();
    let lp = trt.loss(&pp, &tokens).unwrap();
    let lm = trt.loss(&pm, &tokens).unwrap();
    let fd = (lp - lm) as f64 / (2.0 * eps as f64);
    let analytic: f64 = grad.iter().zip(&u).map(|(&g, &du)| g as f64 * du as f64).sum();
    assert!(
        (fd - analytic).abs() < 5e-2 * analytic.abs().max(0.1),
        "fd {fd} vs analytic {analytic}"
    );
}

#[test]
fn transformer_sgd_descends_through_artifacts() {
    let Some(mut rt) = runtime() else { return };
    // Descent proof on one batch (held-out generalization needs hundreds
    // of steps and is demonstrated by examples/e2e_transformer): 20 plain
    // SGD steps at η = 0.1 must drive the training loss down ≈ 1 nat.
    let mut backend = TransformerBackend::new(&mut rt, 1, 1, 21).unwrap();
    let mut params = backend.initial_params();
    let d = params.len();
    let mut grad = vec![0.0f32; d];
    backend.sample_grad(&params, 0, &mut grad);
    let loss0 = backend.last_train_loss;
    for _ in 0..20 {
        for (p, &g) in params.iter_mut().zip(&grad) {
            *p -= 0.1 * g;
        }
        backend.sample_grad(&params, 0, &mut grad);
    }
    let loss1 = backend.last_train_loss;
    assert!(
        loss1 < loss0 - 0.5,
        "plain SGD made no progress: {loss0} → {loss1}"
    );
}

/// The on-device Mem-SGD step artifact (Pallas threshold-compress kernel,
/// Algorithm 1 lines 4-6) must agree with the native `MemSgd::step` over
/// a multi-iteration trajectory: same iterate, same memory, same
/// transmitted coordinates. Cross-layer proof for the *operator itself*.
#[test]
fn memsgd_step_artifact_matches_native_trajectory() {
    use memsgd::compress;
    use memsgd::optim::MemSgd;

    let Some(mut rt) = runtime() else { return };
    let (d, k) = (512usize, 8usize);
    let mut rng = Prng::new(91);

    // Native Algorithm 1.
    let x0: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let mut native = MemSgd::new(x0.clone(), compress::from_spec("top_k:8").unwrap());
    // Artifact state.
    let mut ax = x0.clone();
    let mut am = vec![0.0f32; d];

    for t in 0..12 {
        let grad: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let eta = 0.1 / (1.0 + t as f64);
        native.step(&grad, eta, &mut rng);

        let outs = rt
            .execute(
                "memsgd_step_k8_d512",
                &[
                    Tensor::f32(ax.clone(), &[d, 1]),
                    Tensor::f32(am.clone(), &[d, 1]),
                    Tensor::f32(grad.clone(), &[d, 1]),
                    Tensor::f32(vec![eta as f32], &[]),
                ],
            )
            .expect("execute memsgd_step");
        ax = outs[0].as_f32().unwrap().to_vec();
        am = outs[1].as_f32().unwrap().to_vec();
        let g = outs[2].as_f32().unwrap();

        // Same support size (no magnitude ties in gaussian data) and
        // matching states to fp tolerance (native uses f32 fma in a
        // different order than the HLO graph).
        assert_eq!(g.iter().filter(|&&v| v != 0.0).count(), k, "step {t}");
        for (j, (&a, &b)) in ax.iter().zip(&native.x).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "x[{j}] diverged at step {t}: {a} vs {b}"
            );
        }
        for (j, (&a, &b)) in am.iter().zip(native.memory()).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "m[{j}] diverged at step {t}: {a} vs {b}"
            );
        }
    }
}

/// Conservation through the artifact: g + m' == m + η·grad exactly
/// (the kernel moves mass, never creates it).
#[test]
fn memsgd_step_artifact_conserves_mass() {
    let Some(mut rt) = runtime() else { return };
    let d = 512usize;
    let mut rng = Prng::new(17);
    let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let m: Vec<f32> = (0..d).map(|_| 0.1 * rng.normal_f32()).collect();
    let grad: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let eta = 0.05f32;
    let outs = rt
        .execute(
            "memsgd_step_k8_d512",
            &[
                Tensor::f32(x.clone(), &[d, 1]),
                Tensor::f32(m.clone(), &[d, 1]),
                Tensor::f32(grad.clone(), &[d, 1]),
                Tensor::f32(vec![eta], &[]),
            ],
        )
        .expect("execute");
    let m2 = outs[1].as_f32().unwrap();
    let g = outs[2].as_f32().unwrap();
    for j in 0..d {
        let v = m[j] + eta * grad[j];
        let back = g[j] + m2[j];
        assert!(
            (v - back).abs() <= 1e-6 * (1.0 + v.abs()),
            "mass leak at {j}: {v} vs {back}"
        );
        // Disjoint supports.
        assert!(g[j] == 0.0 || m2[j] == 0.0, "overlap at {j}");
    }
}
