//! Server-free topology suite: the threaded AllReduce ring and Gossip
//! engines against their single-threaded simulations, on every
//! transport backend, plus the multi-process ring runtime.
//!
//! * **Golden trajectories** — `Topology::AllReduce` and
//!   `Topology::Gossip` in wire mode must reproduce the simulated
//!   engines **bit for bit** (loss curve, accounted bits, every extra
//!   the simulation reports) on every `MethodSpec` × `LocalUpdate`
//!   combination and on **every transport backend** (in-process
//!   `Loopback` and kernel-socket `TcpTransport`), with every update
//!   round-tripping through the Elias payload codec and real channels
//!   between threads.
//! * **Trajectory semantics** — AllReduce with n nodes is the same
//!   synchronous-aggregation algorithm as the parameter server: its
//!   loss trajectory must equal `ParamServerSync` point for point (only
//!   the bit accounting differs — ring hops vs upload+broadcast).
//! * **Wire accounting** — the `wire_frame_bits` a run reports must
//!   equal the bytes independently counted at the channel boundary
//!   (`CountingTransport`). The ring convention (the sender of every
//!   directed edge holds the transport's server end) routes all ring
//!   traffic to the broadcast counter; gossip splits across both.
//! * **Cluster runtime** — `RingNodeProcess` peers over localhost TCP
//!   (separate threads standing in for separate processes — the byte
//!   streams are identical) must reproduce the simulated AllReduce
//!   record bit for bit, with node 0 owning the record.

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use memsgd::coordinator::cluster::{RingNodeProcess, RunConfig};
use memsgd::coordinator::net::{Backoff, TcpTransport};
use memsgd::coordinator::transport::{CountingTransport, Loopback, Transport};
use memsgd::coordinator::{Experiment, FailurePolicy, GossipGraph, LocalUpdate, MethodSpec, Topology};
use memsgd::data::Dataset;
use memsgd::experiments::{self, Which};
use memsgd::metrics::RunRecord;
use memsgd::models::LogisticModel;
use memsgd::optim::Schedule;

fn data() -> Dataset {
    memsgd::data::synthetic::epsilon_like(240, 12, 5)
}

/// Every method kind the engines accept (the `wire_protocol.rs` list):
/// memory-carrying sparsifiers, data-dependent operators, memory-free
/// baselines, and the scaled unbiased estimator.
fn all_methods() -> Vec<MethodSpec> {
    [
        "memsgd:top_k:2",
        "memsgd:rand_k:2",
        "memsgd:random_p:0.5",
        "memsgd:block_top_k:3",
        "memsgd:sign",
        "memsgd:threshold:0.25",
        "memsgd:qsgd:8",
        "memsgd:qsgd:8(top_k:2)",
        "memsgd:adaptive:3",
        "sgd",
        "sgd:qsgd:8",
        "sgd:unbiased_rand_k:2",
    ]
    .iter()
    .map(|s| MethodSpec::parse(s).unwrap())
    .collect()
}

fn all_locals() -> Vec<LocalUpdate> {
    vec![LocalUpdate::default(), LocalUpdate::new(2, 3).unwrap()]
}

fn backends() -> Vec<(&'static str, fn() -> Box<dyn Transport>)> {
    vec![
        ("loopback", || Box::new(Loopback) as Box<dyn Transport>),
        ("tcp", || Box::new(TcpTransport) as Box<dyn Transport>),
    ]
}

/// Bit-for-bit record equality: curve (t, accounted bits, f64 loss),
/// step/bit totals, and every extra the simulated engine reports. The
/// wire record may add `wire_*` keys on top; nothing the simulation
/// wrote may differ.
fn assert_records_match(sim: &RunRecord, wired: &RunRecord, label: &str) {
    assert_eq!(sim.method, wired.method, "{label}: method");
    assert_eq!(sim.dataset, wired.dataset, "{label}: dataset");
    assert_eq!(sim.schedule, wired.schedule, "{label}: schedule");
    assert_eq!(sim.steps, wired.steps, "{label}: steps");
    assert_eq!(sim.total_bits, wired.total_bits, "{label}: total_bits");
    assert_eq!(sim.curve, wired.curve, "{label}: loss curve (t/bits/loss, bit-for-bit)");
    for (key, val) in &sim.extra {
        assert_eq!(
            wired.extra.get(key),
            Some(val),
            "{label}: extra[{key}] diverged"
        );
    }
    assert_eq!(wired.extra.get("wire"), Some(&1.0), "{label}: wire marker");
    assert!(wired.extra["wire_frame_bits"] > 0.0, "{label}: no frames counted");
}

fn all_reduce_exp(
    data: &Dataset,
    method: MethodSpec,
    local: LocalUpdate,
    transport: Option<Box<dyn Transport>>,
) -> RunRecord {
    let exp = Experiment::new(LogisticModel::new(data, 1.0 / 240.0))
        .dataset(&data.name)
        .method(method)
        .schedule(Schedule::constant(0.4))
        .topology(Topology::AllReduce { nodes: 3 })
        .steps(540)
        .eval_points(4)
        .seed(7)
        .local_update(local);
    match transport {
        Some(t) => exp.wire_transport(t),
        None => exp,
    }
    .run()
    .unwrap()
}

#[test]
fn threaded_all_reduce_is_bit_identical_on_every_method_and_schedule() {
    let data = data();
    for method in all_methods() {
        for local in all_locals() {
            let label = format!("{} B={} H={}", method.name(), local.batch, local.sync_every);
            let sim = all_reduce_exp(&data, method.clone(), local, None);
            for (backend, make) in backends() {
                let wired = all_reduce_exp(&data, method.clone(), local, Some(make()));
                let label = format!("{label} [{backend}]");
                assert_records_match(&sim, &wired, &label);
                // The ring split must account for every frame bit and
                // every accounted bit: total = reduce hops + gather hops.
                assert_eq!(
                    wired.extra["wire_reduce_frame_bits"]
                        + wired.extra["wire_gather_frame_bits"],
                    wired.extra["wire_frame_bits"],
                    "{label}: per-leg frame bits don't sum to the total"
                );
                assert_eq!(
                    wired.extra["reduce_bits"] + wired.extra["gather_bits"],
                    wired.total_bits as f64,
                    "{label}: accounted legs don't sum to total_bits"
                );
                assert!(
                    wired.extra["wire_reduce_payload_bits"]
                        <= wired.extra["wire_reduce_frame_bits"],
                    "{label}: reduce payload exceeds reduce frames"
                );
                assert!(
                    wired.extra["wire_gather_payload_bits"]
                        <= wired.extra["wire_gather_frame_bits"],
                    "{label}: gather payload exceeds gather frames"
                );
            }
        }
    }
}

fn gossip_exp(
    data: &Dataset,
    method: MethodSpec,
    local: LocalUpdate,
    graph: GossipGraph,
    transport: Option<Box<dyn Transport>>,
) -> RunRecord {
    let exp = Experiment::new(LogisticModel::new(data, 1.0 / 240.0))
        .dataset(&data.name)
        .method(method)
        .schedule(Schedule::constant(0.4))
        .topology(Topology::Gossip { nodes: 3, graph })
        .steps(540)
        .eval_points(4)
        .seed(7)
        .local_update(local);
    match transport {
        Some(t) => exp.wire_transport(t),
        None => exp,
    }
    .run()
    .unwrap()
}

#[test]
fn threaded_gossip_is_bit_identical_on_every_method_graph_and_schedule() {
    let data = data();
    for method in all_methods() {
        for local in all_locals() {
            for graph in [GossipGraph::Complete, GossipGraph::Ring] {
                let label = format!(
                    "gossip {} B={} H={} {}",
                    method.name(),
                    local.batch,
                    local.sync_every,
                    graph.name()
                );
                let sim = gossip_exp(&data, method.clone(), local, graph, None);
                for (backend, make) in backends() {
                    let wired = gossip_exp(&data, method.clone(), local, graph, Some(make()));
                    let label = format!("{label} [{backend}]");
                    assert_records_match(&sim, &wired, &label);
                    assert_eq!(
                        wired.extra["wire_exchange_frame_bits"]
                            + wired.extra["wire_report_frame_bits"],
                        wired.extra["wire_frame_bits"],
                        "{label}: per-kind frame bits don't sum to the total"
                    );
                    assert!(
                        wired.extra["wire_exchange_payload_bits"]
                            <= wired.extra["wire_exchange_frame_bits"],
                        "{label}: exchange payload exceeds exchange frames"
                    );
                    assert!(
                        wired.extra["wire_report_payload_bits"]
                            <= wired.extra["wire_report_frame_bits"],
                        "{label}: report payload exceeds report frames"
                    );
                }
            }
        }
    }
}

/// AllReduce is the same synchronous aggregation as the parameter
/// server — only the fabric (and therefore the bit accounting)
/// differs. The loss trajectories must be equal point for point, and
/// the nodes' accounted sync bits must agree.
#[test]
fn all_reduce_trajectory_equals_param_server_sync() {
    let data = data();
    let run = |topology: Topology| {
        Experiment::new(LogisticModel::new(&data, 1.0 / 240.0))
            .dataset(&data.name)
            .method(MethodSpec::mem_top_k(2))
            .schedule(Schedule::constant(0.4))
            .topology(topology)
            .steps(540)
            .eval_points(4)
            .seed(7)
            .run()
            .unwrap()
    };
    let ps = run(Topology::ParamServerSync { nodes: 3 });
    let ring = run(Topology::AllReduce { nodes: 3 });
    assert_eq!(ps.steps, ring.steps, "steps");
    assert_eq!(ps.curve.len(), ring.curve.len(), "eval points");
    for (p, r) in ps.curve.iter().zip(&ring.curve) {
        assert_eq!(p.t, r.t, "eval round");
        assert_eq!(p.loss, r.loss, "loss at t={} (bit-for-bit)", p.t);
    }
    assert_eq!(
        ps.extra["upload_bits"], ring.extra["upload_bits"],
        "accounted sync bits"
    );
}

#[test]
fn reported_ring_and_gossip_bits_equal_bytes_counted_on_the_channel() {
    let data = data();
    for (backend, make) in backends() {
        // AllReduce: every directed ring edge is a duplex whose sender
        // holds the server end, so every byte of ring traffic lands on
        // the broadcast counter and none on the upload counter.
        let transport = CountingTransport::new(make());
        let counter = transport.counter();
        let up = transport.upload_counter();
        let down = transport.broadcast_counter();
        let rec = Experiment::new(LogisticModel::new(&data, 1.0 / 240.0))
            .dataset(&data.name)
            .method(MethodSpec::mem_top_k(2))
            .schedule(Schedule::constant(0.4))
            .topology(Topology::AllReduce { nodes: 3 })
            .steps(540)
            .eval_points(4)
            .seed(3)
            .wire_transport(Box::new(transport))
            .run()
            .unwrap();
        let label = format!("all-reduce [{backend}]");
        let counted_bits = counter.load(Ordering::Relaxed) * 8;
        assert_eq!(
            rec.extra["wire_frame_bits"], counted_bits as f64,
            "{label}: reported frame bits != bytes on the channel"
        );
        assert_eq!(
            down.load(Ordering::Relaxed) * 8,
            counted_bits,
            "{label}: ring traffic must all flow sender->successor (server ends)"
        );
        assert_eq!(up.load(Ordering::Relaxed), 0, "{label}: no upload-end traffic in a ring");

        // Gossip: edge duplexes put the lower-id node on the server end
        // and monitors put the driver there, so exchange bytes split
        // across both counters and REPORT bytes land on upload — the
        // two counters must still account for every byte.
        let transport = CountingTransport::new(make());
        let counter = transport.counter();
        let up = transport.upload_counter();
        let down = transport.broadcast_counter();
        let rec = Experiment::new(LogisticModel::new(&data, 1.0 / 240.0))
            .dataset(&data.name)
            .method(MethodSpec::mem_top_k(2))
            .schedule(Schedule::constant(0.4))
            .topology(Topology::Gossip { nodes: 3, graph: GossipGraph::Complete })
            .steps(540)
            .eval_points(4)
            .seed(3)
            .wire_transport(Box::new(transport))
            .run()
            .unwrap();
        let label = format!("gossip [{backend}]");
        let counted_bits = counter.load(Ordering::Relaxed) * 8;
        let up_bits = up.load(Ordering::Relaxed) * 8;
        let down_bits = down.load(Ordering::Relaxed) * 8;
        assert_eq!(
            rec.extra["wire_frame_bits"], counted_bits as f64,
            "{label}: reported frame bits != bytes on the channel"
        );
        assert_eq!(
            up_bits + down_bits,
            counted_bits,
            "{label}: direction split loses bytes"
        );
        assert!(up_bits > 0, "{label}: REPORT frames must flow node->driver");
    }
}

/// Deliberately tiny multi-process config (the `cluster_lifecycle.rs`
/// shape): epsilon at a scale that floors n at 64 samples, d = 2000.
fn ring_config(nodes: usize) -> RunConfig {
    RunConfig {
        dataset: "epsilon".into(),
        scale: 100_000,
        seed: 11,
        method: "memsgd:top_k:1".into(),
        schedule: Schedule::constant(0.1),
        steps: 96,
        eval_points: 3,
        nodes,
        local: LocalUpdate::default(),
        topology: "all-reduce".into(),
        network: "1g".into(),
        dim: 2000,
        failure_policy: FailurePolicy::FailFast,
        fault_plan: None,
        start_round: 0,
    }
}

fn fast_backoff() -> Backoff {
    Backoff {
        attempts: 8,
        base: Duration::from_millis(1),
        cap: Duration::from_millis(20),
    }
}

/// Three `RingNodeProcess` peers over localhost TCP sockets, no server
/// process anywhere: node 0's record must reproduce the simulated
/// AllReduce trajectory bit for bit, nodes 1..n return no record, and
/// the whole ring terminates under a watchdog.
#[test]
fn multiprocess_ring_reproduces_the_simulated_trajectory() {
    let nodes = 3;
    let cfg = ring_config(nodes);

    let which = Which::parse(&cfg.dataset).unwrap();
    let data = experiments::dataset(which, cfg.scale, cfg.seed);
    let sim = Experiment::new(LogisticModel::new(&data, 1.0 / data.n() as f64))
        .dataset(&data.name)
        .method(MethodSpec::parse(&cfg.method).unwrap())
        .schedule(cfg.schedule.clone())
        .topology(Topology::AllReduce { nodes })
        .steps(cfg.steps)
        .eval_points(cfg.eval_points)
        .seed(cfg.seed)
        .local_update(cfg.local)
        .run()
        .unwrap();

    // Bind every node first (ports are known before anyone dials), then
    // let each dial its successor concurrently.
    let procs: Vec<RingNodeProcess> = (0..nodes)
        .map(|i| RingNodeProcess::bind("127.0.0.1:0", cfg.clone(), i).unwrap())
        .collect();
    let addrs: Vec<String> =
        procs.iter().map(|p| p.local_addr().unwrap().to_string()).collect();
    let (tx, rx) = mpsc::channel();
    let handles: Vec<_> = procs
        .into_iter()
        .enumerate()
        .map(|(i, p)| {
            let next = addrs[(i + 1) % nodes].clone();
            let tx = tx.clone();
            thread::spawn(move || {
                tx.send((i, p.run(&next, &fast_backoff(), None))).ok();
            })
        })
        .collect();
    drop(tx);

    let mut record = None;
    for _ in 0..nodes {
        let (node, result) = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("ring hung past the watchdog");
        match result.unwrap() {
            Some(rec) => {
                assert_eq!(node, 0, "only node 0 owns the record");
                record = Some(rec);
            }
            None => assert_ne!(node, 0, "node 0 returned no record"),
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    let record = record.expect("node 0 produced no record");

    assert_eq!(sim.method, record.method, "method");
    assert_eq!(sim.dataset, record.dataset, "dataset");
    assert_eq!(sim.schedule, record.schedule, "schedule");
    assert_eq!(sim.steps, record.steps, "steps");
    assert_eq!(sim.total_bits, record.total_bits, "total_bits");
    assert_eq!(sim.curve, record.curve, "loss curve (bit-for-bit)");
    for (key, val) in &sim.extra {
        assert_eq!(record.extra.get(key), Some(val), "extra[{key}] diverged");
    }
    assert_eq!(record.extra.get("cluster"), Some(&1.0), "cluster marker");
    assert_eq!(record.extra.get("wire"), Some(&1.0), "wire marker");
}
