//! Golden-trajectory harness for the local-update schedule
//! (`LocalUpdate { batch, sync_every }`):
//!
//! * a **reference re-implementation** of the pre-local-update
//!   sequential engine (per-sample gradient → `ErrorFeedbackStep::step`
//!   → apply), against which every `B = 1, H = 1` run must be
//!   **bit-for-bit** identical — for every `MethodSpec`, and across all
//!   four topologies at one worker,
//! * the explicit `B = 1, H = 1` schedule ≡ the default schedule on
//!   every `Topology × MethodSpec` combination (extends the PR 1
//!   equality suite),
//! * the `H`-fold bit reduction of syncing every `H` local steps, with
//!   the monotone bit accounting intact,
//! * strict rejection of zero/overflowing schedules at every edge.

use memsgd::compress::CompressorSpec;
use memsgd::coordinator::{Experiment, LocalUpdate, MethodSpec, Topology};
use memsgd::data::synthetic;
use memsgd::models::{GradBackend, LogisticModel};
use memsgd::optim::Schedule;
use memsgd::sim::network::NetworkModel;
use memsgd::util::prng::Prng;

const STEPS: usize = 480;
const ETA: f64 = 0.3;
const SEED: u64 = 11;

fn data() -> memsgd::data::Dataset {
    synthetic::epsilon_like(200, 16, 9)
}

fn all_methods() -> Vec<MethodSpec> {
    vec![
        MethodSpec::mem_top_k(2),
        MethodSpec::mem_rand_k(2),
        MethodSpec::mem(CompressorSpec::RandomP { p: 0.5 }),
        MethodSpec::mem(CompressorSpec::Identity),
        MethodSpec::Sgd,
        MethodSpec::SgdQsgd { levels: 16, eff: None },
        MethodSpec::SgdUnbiasedRandK { k: 2 },
    ]
}

fn all_topologies() -> Vec<Topology> {
    vec![
        Topology::Sequential,
        Topology::SharedMemory { workers: 1 },
        Topology::ParamServerSync { nodes: 1 },
        Topology::ParamServerAsync { nodes: 1, net: NetworkModel::eth_10g() },
    ]
}

/// The pre-local-update sequential engine, replayed verbatim: draw one
/// sample, take one per-sample error-feedback step, apply the
/// compressed update — exactly the loop the engines ran before the
/// `LocalUpdate` schedule existed. Returns the final full loss and the
/// transmitted bits: the golden values every `B = 1, H = 1` run must
/// reproduce bit for bit.
fn golden_sequential(
    dataset: &memsgd::data::Dataset,
    method: &MethodSpec,
    steps: usize,
    eta: f64,
    seed: u64,
) -> (f64, u64) {
    let lam = 1.0 / dataset.n() as f64;
    let mut model = LogisticModel::new(dataset, lam);
    let d = model.dim();
    let n = model.n();
    let mut root = Prng::new(seed);
    let mut rng = root.split(1); // worker 0 of 1, as the engines seed it
    let mut ef = method.error_feedback(d);
    let mut x = vec![0.0f32; d];
    let mut grad = vec![0.0f32; d];
    for _ in 0..steps {
        let i = rng.below(n);
        model.sample_grad(&x, i, &mut grad);
        ef.step(&grad, eta as f32, &mut rng);
        ef.update().sub_from(&mut x);
    }
    (model.full_loss(&x), ef.bits_sent)
}

fn run(
    dataset: &memsgd::data::Dataset,
    method: MethodSpec,
    topology: Topology,
    steps: usize,
    local: Option<LocalUpdate>,
) -> memsgd::metrics::RunRecord {
    let lam = 1.0 / dataset.n() as f64;
    let mut e = Experiment::new(LogisticModel::new(dataset, lam))
        .dataset(&dataset.name)
        .method(method)
        .schedule(Schedule::constant(ETA))
        .topology(topology)
        .steps(steps)
        .eval_points(3)
        .average(false)
        .seed(SEED);
    if let Some(local) = local {
        e = e.local_update(local);
    }
    e.run().unwrap()
}

#[test]
fn b1_h1_reproduces_the_pre_local_update_sequential_engine_bit_for_bit() {
    let data = data();
    for method in all_methods() {
        let (gold_loss, gold_bits) = golden_sequential(&data, &method, STEPS, ETA, SEED);
        let name = method.name();
        for local in [None, Some(LocalUpdate::new(1, 1).unwrap())] {
            let rec = run(&data, method.clone(), Topology::Sequential, STEPS, local);
            assert_eq!(
                rec.final_loss(),
                gold_loss,
                "{name} (local={local:?}): trajectory diverged from the golden engine"
            );
            assert_eq!(rec.total_bits, gold_bits, "{name} (local={local:?}): bits diverged");
        }
    }
}

#[test]
fn b1_h1_one_worker_topologies_replay_the_golden_trajectory() {
    // With one worker there is no concurrency: every topology must land
    // exactly on the golden sequential trajectory (uploads; the
    // parameter server additionally bills its broadcast direction).
    let data = data();
    let b1h1 = LocalUpdate::new(1, 1).unwrap();
    for method in all_methods() {
        let (gold_loss, gold_bits) = golden_sequential(&data, &method, STEPS, ETA, SEED);
        let name = method.name();
        for topology in all_topologies() {
            let rec = run(&data, method.clone(), topology.clone(), STEPS, Some(b1h1));
            assert_eq!(rec.final_loss(), gold_loss, "{name} x {topology:?}");
            let upload_bits = rec
                .extra
                .get("upload_bits")
                .map(|&b| b as u64)
                .unwrap_or(rec.total_bits);
            assert_eq!(upload_bits, gold_bits, "{name} x {topology:?}: upload bits");
        }
    }
}

#[test]
fn explicit_default_schedule_matches_omitted_on_every_combination() {
    // `.local_update(B=1, H=1)` must be indistinguishable from not
    // setting a schedule at all — on every Topology × MethodSpec cell
    // (multi-worker included; the single-threaded engines are exactly
    // deterministic, and SharedMemory at 1 worker has no races).
    let data = data();
    let topologies = vec![
        Topology::Sequential,
        Topology::SharedMemory { workers: 1 },
        Topology::ParamServerSync { nodes: 2 },
        Topology::ParamServerAsync { nodes: 2, net: NetworkModel::eth_10g() },
    ];
    for topology in topologies {
        for method in all_methods() {
            let name = format!("{topology:?} x {}", method.name());
            let a = run(&data, method.clone(), topology.clone(), STEPS, None);
            let b = run(
                &data,
                method.clone(),
                topology.clone(),
                STEPS,
                Some(LocalUpdate::new(1, 1).unwrap()),
            );
            assert_eq!(a.final_loss(), b.final_loss(), "{name}");
            assert_eq!(a.total_bits, b.total_bits, "{name}");
            assert_eq!(a.steps, b.steps, "{name}");
        }
    }
}

#[test]
fn sync_interval_cuts_bits_h_fold_with_monotone_accounting() {
    // Fixed local-step budget, H ∈ {2, 4, 8}: top-2 transmits exactly
    // 2 coordinates per sync, so the bits drop by exactly H; the curve's
    // bit accounting stays monotone and the budget stays respected.
    let data = data();
    let steps = 960; // divisible by every H below
    let base = run(&data, MethodSpec::mem_top_k(2), Topology::Sequential, steps, None);
    assert_eq!(base.steps, steps);
    for h in [2usize, 4, 8] {
        let rec = run(
            &data,
            MethodSpec::mem_top_k(2),
            Topology::Sequential,
            steps,
            Some(LocalUpdate::new(1, h).unwrap()),
        );
        assert_eq!(rec.steps, steps, "H={h}: budget changed");
        assert_eq!(
            base.total_bits,
            rec.total_bits * h as u64,
            "H={h}: expected an exact {h}-fold bit reduction"
        );
        assert!(
            rec.curve.windows(2).all(|w| w[0].bits <= w[1].bits),
            "H={h}: bits not monotone"
        );
        assert!(rec.curve.last().unwrap().bits <= rec.total_bits, "H={h}");
        assert!(rec.final_loss().is_finite(), "H={h}");
    }

    // The same holds per-upload on the parameter server (broadcast
    // excluded via the upload_bits extra).
    let ps_base =
        run(&data, MethodSpec::mem_top_k(2), Topology::ParamServerSync { nodes: 2 }, steps, None);
    let ps_h4 = run(
        &data,
        MethodSpec::mem_top_k(2),
        Topology::ParamServerSync { nodes: 2 },
        steps,
        Some(LocalUpdate::new(1, 4).unwrap()),
    );
    assert_eq!(
        ps_base.extra["upload_bits"] as u64,
        4 * ps_h4.extra["upload_bits"] as u64,
        "parameter-server uploads must drop exactly 4-fold at H=4"
    );
}

#[test]
fn every_topology_runs_the_batched_local_schedule() {
    // Smoke over the full matrix with a non-trivial B and H: finite
    // losses, annotated extras, monotone bits.
    let data = data();
    let local = LocalUpdate::new(2, 4).unwrap();
    let topologies = vec![
        Topology::Sequential,
        Topology::SharedMemory { workers: 2 },
        Topology::ParamServerSync { nodes: 2 },
        Topology::ParamServerAsync { nodes: 2, net: NetworkModel::eth_1g() },
    ];
    for topology in topologies {
        for method in all_methods() {
            let name = format!("{topology:?} x {}", method.name());
            let rec = run(&data, method.clone(), topology.clone(), 640, Some(local));
            assert!(rec.final_loss().is_finite(), "{name}");
            assert!(rec.total_bits > 0, "{name}");
            assert_eq!(rec.extra["batch"], 2.0, "{name}");
            assert_eq!(rec.extra["sync_every"], 4.0, "{name}");
            assert_eq!(rec.extra["grad_samples"], rec.steps as f64 * 2.0, "{name}");
            assert!(
                rec.curve.windows(2).all(|w| w[0].bits <= w[1].bits),
                "{name}: bits not monotone"
            );
        }
    }
}

#[test]
fn minibatch_gradient_is_bit_identical_at_b1_and_mean_at_b_gt_1() {
    let data = data();
    let mut model = LogisticModel::new(&data, 1.0 / data.n() as f64);
    let d = model.dim();
    let mut rng = Prng::new(3);
    let x: Vec<f32> = (0..d).map(|_| 0.3 * rng.normal_f32()).collect();
    let mut a = vec![0.0f32; d];
    let mut b = vec![0.0f32; d];
    for i in [0usize, 17, 199] {
        model.sample_grad(&x, i, &mut a);
        model.sample_grad_batch(&x, &[i], &mut b);
        assert_eq!(a, b, "B=1 gradient must be bit-for-bit at sample {i}");
    }
    // B = 4: the batched path equals the sample mean up to f32 rounding.
    let idx = [0usize, 17, 17, 199];
    model.sample_grad_batch(&x, &idx, &mut b);
    let mut mean = vec![0.0f32; d];
    for &i in &idx {
        model.sample_grad(&x, i, &mut a);
        for (m, &v) in mean.iter_mut().zip(&a) {
            *m += v / idx.len() as f32;
        }
    }
    for (j, (&got, &want)) in b.iter().zip(&mean).enumerate() {
        assert!((got - want).abs() <= 1e-5 + 1e-5 * want.abs(), "coord {j}: {got} vs {want}");
    }
}

#[test]
fn zero_and_overflow_schedules_are_rejected_at_every_edge() {
    assert!(LocalUpdate::new(0, 1).is_err());
    assert!(LocalUpdate::new(1, 0).is_err());
    assert!(LocalUpdate::new(usize::MAX, 2).is_err());
    assert!(LocalUpdate::new(2, usize::MAX).is_err());
    // Literal construction is re-validated by the schedule-accepting APIs
    // — including the builder itself: a zero schedule is refused at
    // run(), not silently clamped.
    assert!(LocalUpdate { batch: 0, sync_every: 1 }.validate().is_err());
    let data = data();
    let lam = 1.0 / data.n() as f64;
    let err = Experiment::new(LogisticModel::new(&data, lam))
        .method(MethodSpec::mem_top_k(1))
        .schedule(Schedule::constant(ETA))
        .steps(64)
        .local_update(LocalUpdate { batch: 0, sync_every: 0 })
        .run()
        .unwrap_err();
    assert!(format!("{err:#}").contains("batch"), "{err:#}");
    assert!(Experiment::new(LogisticModel::new(&data, lam))
        .local_update(LocalUpdate { batch: 1, sync_every: 0 })
        .steps(64)
        .run_single_threaded()
        .is_err());
}
