//! Property suite for the extension modules: exact wire encodings
//! (Elias bitstreams), block top-k, checkpoint round-trips, and the
//! network cost model's algebra.

use memsgd::compress::elias::{
    decode_qsgd, decode_sparse, encode_qsgd, encode_sparse, gamma_bits, BitReader, BitWriter,
};
use memsgd::compress::{self, Compressor, SparseVec, Update};
use memsgd::coordinator::checkpoint::Checkpoint;
use memsgd::optim::MemSgd;
use memsgd::sim::network::{ComputeModel, NetworkModel};
use memsgd::util::check::{check, ensure, ensure_close};
use memsgd::util::prng::Prng;
use memsgd::util::stats;

/// γ/δ codes round-trip any u64 ≥ 1 and cost exactly 2⌊log₂v⌋+1 bits (γ).
#[test]
fn prop_elias_integer_roundtrip() {
    check("elias integers", 400, |rng| {
        let mut w = BitWriter::new();
        let vals: Vec<u64> = (0..1 + rng.below(50))
            .map(|_| 1 + (rng.next_u64() >> (rng.below(63) as u32)))
            .collect();
        for (i, &v) in vals.iter().enumerate() {
            if i % 2 == 0 {
                w.put_gamma(v);
            } else {
                w.put_delta(v);
            }
        }
        let mut r = BitReader::new(w.as_bytes());
        for (i, &v) in vals.iter().enumerate() {
            let got = if i % 2 == 0 {
                r.get_gamma().map_err(|e| e.to_string())?
            } else {
                r.get_delta().map_err(|e| e.to_string())?
            };
            ensure(got == v, format!("value {i}: {got} != {v}"))?;
        }
        Ok(())
    });
}

/// Sparse payloads round-trip exactly (values bit-identical, indices as a
/// set), and the measured bit count matches the reader's consumption.
#[test]
fn prop_sparse_wire_roundtrip() {
    check("sparse wire", 300, |rng| {
        let dim = 1 + rng.below(60_000);
        let nnz = rng.below(dim.min(128) + 1);
        let mut idx = Vec::new();
        rng.sample_distinct(dim, nnz, &mut idx);
        let mut s = SparseVec::new(dim);
        for &i in &idx {
            s.push(i, rng.normal_f32() * 100.0);
        }
        let mut w = BitWriter::new();
        let bits = encode_sparse(&s, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        let back = decode_sparse(&mut r, dim).map_err(|e| e.to_string())?;
        ensure(r.consumed() == bits, "bit accounting drift")?;
        ensure(back.to_dense() == s.to_dense(), "payload mismatch")?;
        // Envelope: footnote-5 accounting (k·(32 + log d) bits + header)
        // should upper-bound the Elias payload for uniformly spread
        // indices by a modest constant.
        let naive = (nnz as u64) * (32 + (dim.max(2) as f64).log2().ceil() as u64) + 64;
        ensure(
            bits <= 2 * naive + 64,
            format!("elias paid {bits} vs naive {naive}"),
        )
    });
}

/// QSGD payloads round-trip and tighter quantization costs fewer bits.
#[test]
fn prop_qsgd_wire_roundtrip_and_monotonicity() {
    check("qsgd wire", 200, |rng| {
        let dim = 1 + rng.below(4_096);
        let density = 0.01 + rng.f64() * 0.2;
        let levels: Vec<i32> = (0..dim)
            .map(|_| {
                if rng.bernoulli(density) {
                    let m = 1 + rng.below(255) as i32;
                    if rng.bernoulli(0.5) {
                        -m
                    } else {
                        m
                    }
                } else {
                    0
                }
            })
            .collect();
        let norm = rng.f32().abs() + 0.1;
        let mut w = BitWriter::new();
        let bits = encode_qsgd(norm, &levels, &mut w);
        let mut r = BitReader::new(w.as_bytes());
        let (n2, l2) = decode_qsgd(&mut r, dim).map_err(|e| e.to_string())?;
        ensure(r.consumed() == bits, "bit accounting drift")?;
        ensure(n2.to_bits() == norm.to_bits(), "norm corrupted")?;
        ensure(l2 == levels, "levels corrupted")?;
        // Halving every magnitude cannot make the payload longer.
        let halved: Vec<i32> = levels.iter().map(|&l| l / 2).collect();
        let mut w2 = BitWriter::new();
        let bits2 = encode_qsgd(norm, &halved, &mut w2);
        ensure(
            bits2 <= bits,
            format!("halved levels cost more: {bits2} > {bits}"),
        )
    });
}

/// γ bit-cost formula agrees with the writer for random values.
#[test]
fn prop_gamma_bits_formula() {
    check("gamma bits", 300, |rng| {
        let v = 1 + (rng.next_u64() >> (1 + rng.below(62) as u32));
        let mut w = BitWriter::new();
        w.put_gamma(v);
        ensure(
            w.bits() == gamma_bits(v),
            format!("v={v}: {} vs {}", w.bits(), gamma_bits(v)),
        )
    });
}

/// Block top-k is a contraction and never emits two picks per block.
#[test]
fn prop_block_top_k_contraction_and_structure() {
    check("block top-k", 300, |rng| {
        let d = 1 + rng.below(512);
        let k = 1 + rng.below(d);
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut comp = compress::from_spec(&format!("block_top_k:{k}")).unwrap();
        let mut out = Update::new_sparse(d);
        comp.compress(&x, rng, &mut out);
        let dense = out.to_dense(d);
        let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
        let kk = comp.contraction_k(d).unwrap();
        ensure(
            stats::l2_norm_sq(&resid)
                <= (1.0 - kk / d as f64) * stats::l2_norm_sq(&x) + 1e-6,
            "contraction violated",
        )?;
        // Structure: at most one nonzero per block of size ⌈d/k⌉.
        if let Update::Sparse(s) = &out {
            let block = d.div_ceil(k.min(d));
            let mut seen = std::collections::HashSet::new();
            for &i in &s.idx {
                ensure(
                    seen.insert(i as usize / block),
                    format!("two picks in block {}", i as usize / block),
                )?;
            }
        }
        Ok(())
    });
}

/// Checkpoint bytes round-trip arbitrary trained states exactly.
#[test]
fn prop_checkpoint_roundtrip() {
    check("checkpoint roundtrip", 60, |rng| {
        let d = 1 + rng.below(300);
        let k = 1 + rng.below(d);
        let spec = format!("top_k:{k}");
        let mut opt = MemSgd::new(
            (0..d).map(|_| rng.normal_f32()).collect(),
            compress::from_spec(&spec).unwrap(),
        );
        let steps = rng.below(40);
        let grad: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        for _ in 0..steps {
            opt.step(&grad, 0.1, rng);
        }
        let ck = Checkpoint::capture(&opt, &spec, rng, None);
        let back = Checkpoint::from_bytes(&ck.to_bytes()).map_err(|e| e.to_string())?;
        ensure(back.x == opt.x, "x corrupted")?;
        ensure(back.m == opt.memory(), "m corrupted")?;
        ensure(back.t == opt.t, "t corrupted")?;
        ensure(back.bits_sent == opt.bits_sent, "bits corrupted")?;
        ensure(back.rng_state == rng.state(), "rng corrupted")
    });
}

/// Resume-equivalence: split any run at a random point; the final state
/// matches the uninterrupted run bit-for-bit.
#[test]
fn prop_checkpoint_resume_equivalence() {
    check("checkpoint resume", 40, |rng| {
        let d = 2 + rng.below(100);
        let spec = match rng.below(3) {
            0 => "top_k:1".to_string(),
            1 => format!("rand_k:{}", 1 + rng.below(d / 2 + 1)),
            _ => "sign".to_string(),
        };
        let total = 20 + rng.below(60);
        let cut = 1 + rng.below(total - 1);
        let seed = rng.next_u64();
        let grad_at = |t: usize| -> Vec<f32> {
            (0..d).map(|i| ((i * 31 + t * 7) as f32 * 0.013).sin()).collect()
        };

        let mut full = MemSgd::new(vec![0.0; d], compress::from_spec(&spec).unwrap());
        let mut full_rng = Prng::new(seed);
        for t in 0..total {
            full.step(&grad_at(t), 0.07, &mut full_rng);
        }

        let mut part = MemSgd::new(vec![0.0; d], compress::from_spec(&spec).unwrap());
        let mut part_rng = Prng::new(seed);
        for t in 0..cut {
            part.step(&grad_at(t), 0.07, &mut part_rng);
        }
        let ck = Checkpoint::capture(&part, &spec, &part_rng, None);
        let (mut resumed, mut rng2, _) = ck.restore().map_err(|e| e.to_string())?;
        for t in cut..total {
            resumed.step(&grad_at(t), 0.07, &mut rng2);
        }
        ensure(resumed.x == full.x, format!("x diverged ({spec}, cut {cut})"))?;
        ensure(resumed.memory() == full.memory(), "m diverged")?;
        ensure(rng2.state() == full_rng.state(), "rng diverged")
    });
}

/// Network model: round time decomposes additively and is monotone in
/// bits, latency, and inverse bandwidth.
#[test]
fn prop_network_round_monotonicity() {
    check("network monotone", 200, |rng| {
        let lat = rng.f64() * 1e-3;
        let bw = 1e6 + rng.f64() * 1e11;
        let net = NetworkModel::new("t", lat, bw);
        let up = rng.next_u64() % 1_000_000_000;
        let down = rng.next_u64() % 1_000_000_000;
        let comp = rng.f64() * 0.1;
        let r = net.round_s(up, down, comp);
        ensure_close(
            r,
            comp + 2.0 * lat + (up + down) as f64 / bw,
            1e-12,
            1e-15,
            "decomposition",
        )?;
        ensure(net.round_s(up + 1024, down, comp) >= r, "not monotone in up")?;
        let faster = NetworkModel::new("t2", lat, bw * 2.0);
        ensure(
            faster.round_s(up, down, comp) <= r,
            "not monotone in bandwidth",
        )
    });
}

/// Compute model scales linearly in grads and straggler factor.
#[test]
fn prop_compute_model_linear() {
    check("compute linear", 100, |rng| {
        let per = 1e-10 + rng.f64() * 1e-8;
        let coords = 1.0 + rng.f64() * 50_000.0;
        let mut cm = ComputeModel::new(per, coords);
        let one = cm.round_s(1);
        ensure_close(cm.round_s(7), 7.0 * one, 1e-9, 0.0, "linear in grads")?;
        cm.straggler_factor = 2.5;
        ensure_close(cm.round_s(1), 2.5 * one, 1e-9, 0.0, "linear in straggler")
    });
}

/// Every registered compressor survives adversarial inputs: zeros,
/// constants, single spikes, NaN-free subnormals, d = 1.
#[test]
fn prop_compressors_survive_edge_inputs() {
    let specs = [
        "top_k:1",
        "rand_k:1",
        "random_p:0.5",
        "block_top_k:2",
        "qsgd:4",
        "sign",
        "threshold:0.5",
        "identity",
    ];
    check("edge inputs", 150, |rng| {
        let spec = specs[rng.below(specs.len())];
        let d = 1 + rng.below(64);
        let x: Vec<f32> = match rng.below(4) {
            0 => vec![0.0; d],
            1 => vec![1.0e-40; d], // subnormal
            2 => {
                let mut v = vec![0.0; d];
                v[rng.below(d)] = 1.0e30;
                v
            }
            _ => vec![-7.25; d],
        };
        let mut comp = compress::from_spec(spec).unwrap();
        let mut out = Update::new_sparse(d);
        let bits = comp.compress(&x, rng, &mut out);
        let dense = out.to_dense(d);
        ensure(dense.len() == d, "dimension corrupted")?;
        ensure(
            dense.iter().all(|v| v.is_finite()),
            format!("{spec} emitted non-finite values"),
        )?;
        ensure(bits < (d as u64 + 8) * 64, format!("{spec} absurd bit count {bits}"))
    });
}
