//! Unified experiment API integration tests:
//!
//! * every `Topology` × `MethodSpec` combination smoke-runs on a tiny
//!   synthetic dataset with monotone communicated-bits accounting,
//! * 1-worker `SharedMemory` and `ParamServerSync` reproduce the
//!   `Sequential` trajectory exactly (the engines share one
//!   error-feedback step and one worker-seeding scheme),
//! * the strict spec parsing contract (no silently ignored components).

use memsgd::compress::{from_spec, CompressorSpec};
use memsgd::coordinator::{Experiment, MethodSpec, Topology};
use memsgd::data::synthetic;
use memsgd::models::LogisticModel;
use memsgd::optim::Schedule;
use memsgd::sim::network::NetworkModel;

fn data() -> memsgd::data::Dataset {
    synthetic::epsilon_like(200, 16, 9)
}

fn all_methods() -> Vec<MethodSpec> {
    vec![
        MethodSpec::mem_top_k(2),
        MethodSpec::mem_rand_k(2),
        MethodSpec::mem(CompressorSpec::RandomP { p: 0.5 }),
        MethodSpec::mem(CompressorSpec::Identity),
        MethodSpec::Sgd,
        MethodSpec::SgdQsgd { levels: 16, eff: None },
        MethodSpec::SgdUnbiasedRandK { k: 2 },
    ]
}

fn all_topologies() -> Vec<Topology> {
    vec![
        Topology::Sequential,
        Topology::SharedMemory { workers: 2 },
        Topology::ParamServerSync { nodes: 2 },
        Topology::ParamServerAsync { nodes: 2, net: NetworkModel::eth_10g() },
    ]
}

#[test]
fn every_topology_method_combination_smoke_runs() {
    let data = data();
    let lam = 1.0 / data.n() as f64;
    for topology in all_topologies() {
        for method in all_methods() {
            let label = format!("{:?} x {}", topology, method.name());
            let rec = Experiment::new(LogisticModel::new(&data, lam))
                .dataset(&data.name)
                .method(method)
                .schedule(Schedule::constant(0.1))
                .topology(topology.clone())
                .steps(400)
                .eval_points(4)
                .average(false)
                .seed(3)
                .run()
                .unwrap_or_else(|e| panic!("{label}: {e:#}"));
            assert!(rec.final_loss().is_finite(), "{label}: non-finite loss");
            assert!(rec.total_bits > 0, "{label}: no bits accounted");
            assert!(rec.steps > 0, "{label}: no steps recorded");
            assert!(!rec.curve.is_empty(), "{label}: empty curve");
            // Communicated bits are cumulative: monotone along the curve,
            // ending at the recorded total.
            assert!(
                rec.curve.windows(2).all(|w| w[0].bits <= w[1].bits),
                "{label}: bits not monotone"
            );
            assert!(
                rec.curve.last().unwrap().bits <= rec.total_bits,
                "{label}: curve bits exceed total"
            );
        }
    }
}

#[test]
fn one_worker_shared_memory_matches_sequential_exactly() {
    // The cross-topology consistency contract: with one worker there is
    // no concurrency and no averaging, so SharedMemory { workers: 1 }
    // and ParamServerSync { nodes: 1 } replay the Sequential trajectory
    // bit for bit for the deterministic memsgd:top_k:1.
    let data = data();
    let lam = 1.0 / data.n() as f64;
    let run = |topology: Topology| {
        Experiment::new(LogisticModel::new(&data, lam))
            .dataset(&data.name)
            .method(MethodSpec::mem_top_k(1))
            .schedule(Schedule::constant(0.5))
            .topology(topology)
            .steps(600)
            .eval_points(3)
            .average(false)
            .seed(11)
            .run()
            .unwrap()
    };
    let seq = run(Topology::Sequential);
    let shm = run(Topology::SharedMemory { workers: 1 });
    let ps = run(Topology::ParamServerSync { nodes: 1 });

    assert_eq!(
        seq.final_loss(),
        shm.final_loss(),
        "1-worker shared memory diverged from sequential"
    );
    assert_eq!(
        seq.final_loss(),
        ps.final_loss(),
        "1-node parameter server diverged from sequential"
    );
    // Upload accounting matches exactly; the parameter server additionally
    // bills the broadcast direction.
    assert_eq!(seq.total_bits, shm.total_bits);
    assert_eq!(seq.total_bits, ps.extra["upload_bits"] as u64);
    assert!(ps.total_bits > seq.total_bits, "broadcast not accounted");

    // And the asynchronous server with one worker (zero staleness) lands
    // on the same trajectory too.
    let ps_async = run(Topology::ParamServerAsync { nodes: 1, net: NetworkModel::eth_10g() });
    assert_eq!(seq.final_loss(), ps_async.final_loss());
    assert_eq!(ps_async.extra["max_staleness"], 0.0);
}

#[test]
fn multi_worker_topologies_still_converge() {
    let data = data();
    let lam = 1.0 / data.n() as f64;
    for topology in all_topologies() {
        let rec = Experiment::new(LogisticModel::new(&data, lam))
            .dataset(&data.name)
            .method(MethodSpec::mem_top_k(2))
            .schedule(Schedule::constant(0.5))
            .topology(topology.clone())
            .steps(4_000)
            .eval_points(4)
            .average(false)
            .seed(5)
            .run()
            .unwrap();
        assert!(
            rec.final_loss() < 0.66,
            "{topology:?}: stuck at {}",
            rec.final_loss()
        );
    }
}

#[test]
fn deterministic_given_seed_across_topologies() {
    let data = data();
    let lam = 1.0 / data.n() as f64;
    // Threads race, so exact determinism is only promised for the
    // single-threaded engines.
    for topology in [
        Topology::Sequential,
        Topology::ParamServerSync { nodes: 3 },
        Topology::ParamServerAsync { nodes: 3, net: NetworkModel::eth_1g() },
    ] {
        let run = || {
            Experiment::new(LogisticModel::new(&data, lam))
                .dataset(&data.name)
                .method(MethodSpec::mem_rand_k(2))
                .schedule(Schedule::constant(0.2))
                .topology(topology.clone())
                .steps(600)
                .eval_points(3)
                .seed(13)
                .run()
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.final_loss(), b.final_loss(), "{topology:?}");
        assert_eq!(a.total_bits, b.total_bits, "{topology:?}");
    }
}

#[test]
fn strict_spec_parsing_end_to_end() {
    // Trailing junk is rejected at every parse edge.
    assert!(from_spec("top_k:1:junk").is_err());
    assert!(MethodSpec::parse("memsgd:top_k:1:junk").is_err());
    assert!(MethodSpec::parse("sgd:qsgd:16:71:junk").is_err());
    // The typed spec is what the infallible paths hold.
    let m = MethodSpec::parse("memsgd:top_k:3").unwrap();
    assert_eq!(m, MethodSpec::mem_top_k(3));
    assert_eq!(m.name(), "memsgd(top_3)");
    assert_eq!(m.contraction_k(30), Some(3.0));
    assert_eq!(m.spec_string(), "memsgd:top_k:3");
}

#[test]
fn run_single_threaded_covers_non_replicating_backends() {
    // Backends that cannot be cloned across threads (the PJRT case) can
    // still run every topology except SharedMemory.
    let data = data();
    let lam = 1.0 / data.n() as f64;
    let build = |topology: Topology| {
        Experiment::new(LogisticModel::new(&data, lam))
            .dataset(&data.name)
            .method(MethodSpec::mem_top_k(1))
            .schedule(Schedule::constant(0.3))
            .topology(topology)
            .steps(300)
            .eval_points(3)
            .seed(4)
    };
    for topology in [
        Topology::Sequential,
        Topology::ParamServerSync { nodes: 2 },
        Topology::ParamServerAsync { nodes: 2, net: NetworkModel::eth_10g() },
    ] {
        let rec = build(topology.clone()).run_single_threaded().unwrap();
        assert!(rec.final_loss().is_finite(), "{topology:?}");
    }
    let err = build(Topology::SharedMemory { workers: 2 })
        .run_single_threaded()
        .unwrap_err();
    assert!(format!("{err:#}").contains("SharedMemory"), "{err:#}");
}

#[test]
fn parse_method_builder_edge() {
    let data = data();
    let lam = 1.0 / data.n() as f64;
    let rec = Experiment::new(LogisticModel::new(&data, lam))
        .dataset(&data.name)
        .parse_method("memsgd:top_k:1")
        .unwrap()
        .schedule(Schedule::constant(0.3))
        .steps(300)
        .eval_points(3)
        .seed(2)
        .run()
        .unwrap();
    assert_eq!(rec.method, "memsgd(top_1)");
    assert!(
        Experiment::new(LogisticModel::new(&data, lam))
            .parse_method("memsgd:top_k:1:junk")
            .is_err()
    );
}
