//! Parallel (Algorithm 2) integration: lock-free shared-memory Mem-SGD
//! must stay correct under real concurrency — final losses comparable to
//! the sequential run, all compressors, both dataset shapes, bit
//! accounting intact across workers.

use memsgd::coordinator::parallel::{self, ParallelConfig};
use memsgd::coordinator::train::{self, TrainConfig};
use memsgd::data::synthetic;
use memsgd::optim::Schedule;

fn epsilon() -> memsgd::data::Dataset {
    synthetic::epsilon_like(1_000, 64, 11)
}

#[test]
fn parallel_matches_sequential_quality() {
    // Same budget, same constant rate: the 4-worker lock-free run must
    // land in the same loss ballpark as the 1-worker (= sequential
    // modulo memory layout) run.
    let data = epsilon();
    let budget = 12_000usize;
    let run_w = |workers: usize| {
        parallel::run(
            &data,
            &ParallelConfig {
                workers,
                steps_per_worker: budget,
                fixed_total_steps: true,
                compressor: "top_k:2".into(),
                schedule: Schedule::constant(0.5),
                seed: 3,
                ..Default::default()
            },
        )
        .unwrap()
        .final_loss()
    };
    let sequential = {
        let mut cfg = TrainConfig {
            method: "memsgd:top_k:2".into(),
            schedule: Schedule::constant(0.5),
            steps: budget,
            eval_points: 2,
            seed: 3,
            ..TrainConfig::default()
        };
        cfg.average = false;
        train::run(&data, &cfg).unwrap().final_loss()
    };
    let w1 = run_w(1);
    let w4 = run_w(4);
    assert!((w1 - sequential).abs() < 0.05, "w1 {w1} vs seq {sequential}");
    assert!((w4 - sequential).abs() < 0.08, "w4 {w4} vs seq {sequential}");
}

#[test]
fn all_compressors_survive_concurrency() {
    let data = epsilon();
    for comp in ["top_k:1", "rand_k:2", "random_p:0.5", "identity", "qsgd:16"] {
        let rec = parallel::run(
            &data,
            &ParallelConfig {
                workers: 3,
                steps_per_worker: 6_000,
                fixed_total_steps: true,
                compressor: comp.into(),
                schedule: Schedule::constant(0.3),
                seed: 7,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            rec.final_loss().is_finite() && rec.final_loss() < 0.70,
            "{comp}: loss {}",
            rec.final_loss()
        );
    }
}

#[test]
fn sparse_dataset_parallel() {
    let data = synthetic::rcv1_like(1_500, 2_048, 0.01, 13);
    let n = data.n();
    let rec = parallel::run(
        &data,
        &ParallelConfig {
            workers: 4,
            steps_per_worker: 4 * n,
            fixed_total_steps: true,
            compressor: "top_k:10".into(),
            schedule: Schedule::inv_t(2.0, 1.0 / n as f64, 2_048.0),
            seed: 17,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(
        rec.final_loss() < std::f64::consts::LN_2,
        "sparse parallel run stuck at {}",
        rec.final_loss()
    );
}

#[test]
fn worker_seeds_are_decorrelated() {
    // Two workers with the same base seed must not replay identical
    // sample sequences: with decorrelated streams, doubling the worker
    // count at *fixed per-worker* steps doubles coverage, improving (or
    // at least not hurting) the loss.
    let data = epsilon();
    let run_w = |workers: usize| {
        parallel::run(
            &data,
            &ParallelConfig {
                workers,
                steps_per_worker: 2_000,
                fixed_total_steps: false, // per-worker budget
                compressor: "top_k:2".into(),
                schedule: Schedule::constant(0.5),
                seed: 23,
                ..Default::default()
            },
        )
        .unwrap()
    };
    let w1 = run_w(1);
    let w4 = run_w(4);
    assert_eq!(w4.steps, 8_000);
    // Margin note: this drives REAL lock-free threads, so the exact
    // final loss depends on OS scheduling; on a loaded 1-core box the
    // interleaving can cost a few hundredths. 0.05 still rejects the
    // failure mode under test (correlated streams replaying identical
    // samples keep W=4 ≈ W=1 instead of improving coverage).
    assert!(
        w4.final_loss() <= w1.final_loss() + 0.05,
        "4 workers with 4x work should not be worse: {} vs {}",
        w4.final_loss(),
        w1.final_loss()
    );
}

#[test]
fn deterministic_for_single_worker() {
    // With one worker there is no race: repeated runs must agree exactly.
    let data = epsilon();
    let cfg = ParallelConfig {
        workers: 1,
        steps_per_worker: 1_000,
        fixed_total_steps: false,
        compressor: "rand_k:3".into(),
        schedule: Schedule::constant(0.2),
        seed: 29,
        ..Default::default()
    };
    let a = parallel::run(&data, &cfg).unwrap();
    let b = parallel::run(&data, &cfg).unwrap();
    assert_eq!(a.final_loss(), b.final_loss());
    assert_eq!(a.total_bits, b.total_bits);
}
