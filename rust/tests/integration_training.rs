//! Training integration: convergence across module boundaries (data →
//! model → compressor → optimizer → averaging) plus direct validation
//! of the paper's theory on live runs:
//!
//! * Lemma 3.2 — the memory norm stays under `η_t² · (4α/(α−4)) (d/k)² G²`.
//! * Eq. (12) — the virtual iterate tracks uncompressed SGD exactly.
//! * Theorem 2.4 — the weighted average converges at the SGD rate; the
//!   suboptimality shrinks ~linearly in T once `T ≳ (d/k)√κ`.
//! * Remark 2.3 — ultra-sparsification (k < 1) still converges.

use memsgd::compress::from_spec;
use memsgd::coordinator::train::{self, TrainConfig};
use memsgd::data::synthetic;
use memsgd::models::{GradBackend, LeastSquaresModel, LogisticModel};
use memsgd::optim::{MemSgd, Schedule, WeightedAverage};
use memsgd::util::prng::Prng;
use memsgd::util::stats;

#[test]
fn memory_norm_obeys_lemma_3_2() {
    // Run Mem-SGD top-k with the Theorem-2.4 stepsizes and check the
    // Lemma 3.2 bound E||m_t||^2 <= eta_t^2 * (4a/(a-4)) * (d/k)^2 * G^2
    // pointwise along the trajectory (with alpha = 5, the Remark 2.6
    // choice; G^2 = 1 since rows are unit-norm and lam*|x| stays small).
    let data = synthetic::epsilon_like(500, 64, 3);
    let d = 64usize;
    let k = 2usize;
    let n = data.n();
    let mu = 1.0 / n as f64; // lam = mu (strong convexity from L2 term)
    let alpha = 5.0f64;
    let a = (alpha + 2.0) * d as f64 / k as f64; // Remark 2.5 shift
    let mut model = LogisticModel::with_paper_lambda(&data);
    let mut opt = MemSgd::new(vec![0.0; d], from_spec(&format!("top_k:{k}")).unwrap());
    let mut rng = Prng::new(5);
    let mut grad = vec![0.0f32; d];
    let g_sq = 1.1f64; // unit-norm rows + tiny reg: ||grad|| <= ~1.05
    let factor = 4.0 * alpha / (alpha - 4.0) * (d as f64 / k as f64).powi(2) * g_sq;
    for t in 0..3_000 {
        let i = (t * 7919) % n;
        model.sample_grad(&opt.x, i, &mut grad);
        let eta = 8.0 / (mu * (a + t as f64));
        opt.step(&grad, eta, &mut rng);
        let bound = eta * eta * factor;
        let m2 = opt.memory_norm_sq();
        assert!(
            m2 <= bound,
            "t={t}: ||m||^2 = {m2} exceeds Lemma 3.2 bound {bound}"
        );
    }
}

#[test]
fn virtual_iterate_equals_uncompressed_sgd_trajectory() {
    // Eq. (12): x_t - m_t == x0 - sum eta_j grad_j, for gradients
    // evaluated at the *compressed* trajectory. We replay the exact
    // gradient sequence to verify bit-for-bit (f32 tolerance).
    let data = synthetic::rcv1_like(300, 512, 0.02, 7);
    let d = 512usize;
    let mut model = LogisticModel::with_paper_lambda(&data);
    let mut opt = MemSgd::new(vec![0.0; d], from_spec("top_k:5").unwrap());
    let mut rng = Prng::new(11);
    let mut grad = vec![0.0f32; d];
    let mut virt = vec![0.0f32; d];
    for t in 0..1_000 {
        let i = (t * 104_729) % data.n();
        model.sample_grad(&opt.x, i, &mut grad);
        let eta = 0.5 / (1.0 + t as f64);
        for (v, &g) in virt.iter_mut().zip(&grad) {
            *v -= eta as f32 * g;
        }
        opt.step(&grad, eta, &mut rng);
    }
    let err = stats::rel_l2_err(&opt.virtual_iterate(), &virt);
    assert!(err < 1e-4, "virtual iterate drifted: rel err {err}");
}

#[test]
fn suboptimality_shrinks_with_t_at_sgd_rate() {
    // Theorem 2.4: for T beyond the transient, doubling T should roughly
    // halve f(x̄_T) − f* (the O(G²/μT) term dominates). Least-squares
    // gives us f* in closed form.
    let data = synthetic::epsilon_like(400, 24, 9);
    let lam = 0.05;
    let model = LeastSquaresModel::new(&data, lam);
    let xstar = model.solve_exact();
    let fstar = {
        let mut m = LeastSquaresModel::new(&data, lam);
        m.full_loss(&xstar)
    };

    let run = |steps: usize| -> f64 {
        let d = data.d();
        let k = 1.0f64;
        let a = Schedule::paper_shift(d, k, 1.0);
        let sched = Schedule::inv_t(2.0, lam, a);
        let mut m = LeastSquaresModel::new(&data, lam);
        let mut opt = MemSgd::new(vec![0.0; d], from_spec("top_k:1").unwrap());
        let mut avg = WeightedAverage::new(d, a);
        let mut rng = Prng::new(13);
        let mut grad = vec![0.0f32; d];
        for t in 0..steps {
            let i = rng.below(data.n());
            m.sample_grad(&opt.x, i, &mut grad);
            opt.step(&grad, sched.eta(t), &mut rng);
            avg.update(&opt.x);
        }
        m.full_loss(&avg.average()) - fstar
    };

    let e1 = run(2_000);
    let e2 = run(8_000);
    let e3 = run(32_000);
    assert!(e1 > 0.0 && e2 > 0.0 && e3 > 0.0, "{e1} {e2} {e3}");
    // 4x more steps must cut the gap by at least ~2x each time (rate ~1/T
    // with stochastic noise; require a conservative factor).
    assert!(e2 < e1 / 2.0, "e(8k)={e2} not << e(2k)={e1}");
    assert!(e3 < e2 / 2.0, "e(32k)={e3} not << e(8k)={e2}");
}

#[test]
fn ultra_sparsification_converges() {
    // Remark 2.3: k = p = 0.5 < 1 — less than one coordinate per step on
    // average — still converges with shift a ∝ d/p.
    let data = synthetic::epsilon_like(300, 16, 2);
    let cfg = TrainConfig {
        method: "memsgd:random_p:0.5".into(),
        steps: 20_000,
        eval_points: 4,
        seed: 3,
        ..TrainConfig::default()
    }
    .with_paper_schedule(16, 300, 2.0, 1.0)
    .unwrap();
    // Shift must reflect the fractional k: a = d/p = 32.
    match cfg.schedule {
        Schedule::InvT { shift, .. } => assert_eq!(shift, 32.0),
        _ => panic!(),
    }
    let rec = train::run(&data, &cfg).unwrap();
    assert!(
        rec.final_loss() < 0.67,
        "ultra-sparsified run stuck at {}",
        rec.final_loss()
    );
    // On average half a coordinate per iteration: nnz bits ≈ steps/2 · 36.
    let expected_bits = (cfg.steps as u64 / 2) * (32 + 4);
    let tol = expected_bits / 10;
    assert!(
        rec.total_bits.abs_diff(expected_bits) < tol,
        "bits {} vs expected ~{expected_bits}",
        rec.total_bits
    );
}

#[test]
fn sparse_dataset_end_to_end() {
    // RCV1-like CSR data through the whole pipeline.
    let data = synthetic::rcv1_like(2_000, 4_096, 0.005, 17);
    let cfg = TrainConfig {
        method: "memsgd:top_k:10".into(),
        steps: 3 * data.n(),
        eval_points: 6,
        seed: 19,
        ..TrainConfig::default()
    }
    .with_paper_schedule(data.d(), data.n(), 2.0, 10.0)
    .unwrap();
    let rec = train::run(&data, &cfg).unwrap();
    let first = rec.curve[0].loss;
    assert!(
        rec.final_loss() < first - 0.02,
        "no progress on sparse data: {first} → {}",
        rec.final_loss()
    );
}

#[test]
fn all_methods_run_one_epoch_without_nans() {
    let data = synthetic::epsilon_like(200, 32, 23);
    for method in [
        "sgd",
        "memsgd:top_k:1",
        "memsgd:rand_k:3",
        "memsgd:random_p:0.25",
        "memsgd:identity",
        "sgd:qsgd:4",
        "sgd:qsgd:16",
        "sgd:unbiased_rand_k:4",
    ] {
        let cfg = TrainConfig {
            method: method.into(),
            steps: data.n(),
            eval_points: 3,
            seed: 29,
            ..TrainConfig::default()
        }
        .with_paper_schedule(32, 200, 2.0, 1.0)
        .unwrap();
        let rec = train::run(&data, &cfg).unwrap();
        assert!(
            rec.curve.iter().all(|p| p.loss.is_finite()),
            "{method} produced non-finite loss"
        );
    }
}

#[test]
fn epochs_of_memsgd_beat_one_epoch() {
    let data = synthetic::epsilon_like(500, 64, 31);
    let run = |epochs: usize| {
        let cfg = TrainConfig {
            method: "memsgd:top_k:2".into(),
            steps: epochs * data.n(),
            eval_points: 3,
            seed: 37,
            ..TrainConfig::default()
        }
        .with_paper_schedule(64, 500, 2.0, 1.0)
        .unwrap();
        train::run(&data, &cfg).unwrap().final_loss()
    };
    assert!(run(4) < run(1), "more epochs should not hurt");
}
