//! Chaos suite: deterministic fault injection end to end.
//!
//! * **Plan replay** — a hand-rolled proptest over seeds: any
//!   `--fault-plan` spec materializes into a byte-identical schedule
//!   every time it is expanded, schedules respect the shape invariants
//!   (victims in range, fault rounds in `[1, rounds)`), and different
//!   seeds actually produce different schedules.
//! * **Policy trajectories** — a `drop-round` run with an injected kill
//!   is pinned bit-for-bit across the simulated engine, the threaded
//!   loopback wire engine, and real TCP sockets: the server outlives
//!   the death, folds the surviving quorum, and every path agrees on
//!   the loss curve and the accounted bits.
//! * **Stress** — 16 workers with three seeded deaths under `drop-round`
//!   terminate under a watchdog with a finite model.
//! * **Cluster runtime** — a worker dialing before the server binds
//!   retries through the handshake; `wait-rejoin` re-syncs a
//!   replacement worker from a model `SNAPSHOT` on both I/O backends;
//!   a checkpointed server restart resumes mid-run and completes.

use std::collections::HashSet;
use std::net::TcpListener;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use memsgd::coordinator::cluster::{run_worker, ClusterServer, IoBackend, RunConfig};
use memsgd::coordinator::net::{Backoff, Hello, TcpTransport};
use memsgd::coordinator::transport::Loopback;
use memsgd::coordinator::{
    Experiment, FailurePolicy, FaultSpec, LocalUpdate, MethodSpec, Topology,
};
use memsgd::experiments::{self, Which};
use memsgd::metrics::RunRecord;
use memsgd::models::LogisticModel;
use memsgd::optim::Schedule;

// ---------------------------------------------------------------------------
// Plan replay: determinism across seeds
// ---------------------------------------------------------------------------

/// Hand-rolled proptest: 50 seeds × every fault class. Expanding the
/// same spec twice must agree byte for byte (`describe()` is the replay
/// surface CI diffs), every fault must target a real node in a round in
/// `[1, rounds)` (round 0 always completes), and kill plans must name
/// exactly `k` distinct victims.
#[test]
fn any_fault_plan_seed_replays_byte_identically() {
    let (nodes, rounds) = (8usize, 24usize);
    for seed in 0..50u64 {
        for class in ["kill:2", "drop:3", "corrupt:1", "delay:2:40"] {
            let spec = FaultSpec::parse(&format!("{class}:{seed}")).unwrap().unwrap();
            let a = spec.plan(nodes, rounds).unwrap();
            let b = spec.plan(nodes, rounds).unwrap();
            assert_eq!(a, b, "{class}:{seed}");
            assert_eq!(a.describe(), b.describe(), "{class}:{seed}");
            assert!(!a.is_empty(), "{class}:{seed} scheduled nothing");
            let mut victims = 0usize;
            for node in 0..nodes {
                let faults = a.faults_for(node);
                victims += usize::from(!faults.is_empty());
                for f in faults {
                    assert!(
                        (1..rounds as u64).contains(&f.at),
                        "{class}:{seed} node {node} fires at {} (rounds {rounds})",
                        f.at
                    );
                }
            }
            let k = class.split(':').nth(1).unwrap().parse::<usize>().unwrap();
            assert_eq!(victims, k, "{class}:{seed} victim count");
            // Nothing outside the node range (faults_for covered 0..nodes;
            // sim_deaths bails on out-of-range targets).
            if class.starts_with("kill") {
                let deaths = a.sim_deaths(nodes).unwrap();
                assert_eq!(deaths.iter().flatten().count(), k, "{class}:{seed}");
            }
        }
    }
    // The seed must matter: 50 kill schedules cannot collapse to a few.
    let distinct: HashSet<String> = (0..50u64)
        .map(|s| {
            FaultSpec::parse(&format!("kill:2:{s}"))
                .unwrap()
                .unwrap()
                .plan(nodes, rounds)
                .unwrap()
                .describe()
        })
        .collect();
    assert!(distinct.len() > 25, "only {} distinct schedules from 50 seeds", distinct.len());
}

// ---------------------------------------------------------------------------
// Drop-round: simulated ≡ loopback ≡ TCP, bit for bit
// ---------------------------------------------------------------------------

const DROP_SEED: u64 = 11;
const DROP_PLAN: &str = "kill:1:77";

fn drop_round_run(wire: Option<&str>) -> RunRecord {
    let data = experiments::dataset(Which::parse("epsilon").unwrap(), 100_000, DROP_SEED);
    let mut exp = Experiment::new(LogisticModel::new(&data, 1.0 / data.n() as f64))
        .dataset(&data.name)
        .method(MethodSpec::parse("memsgd:top_k:1").unwrap())
        .schedule(Schedule::constant(0.1))
        .topology(Topology::ParamServerSync { nodes: 4 })
        .steps(96)
        .eval_points(3)
        .seed(DROP_SEED)
        .failure_policy(FailurePolicy::DropRound { min_quorum: 2 })
        .fault_plan(FaultSpec::parse(DROP_PLAN).unwrap().unwrap());
    exp = match wire {
        None => exp,
        Some("loopback") => exp.wire_transport(Box::new(Loopback)),
        Some("tcp") => exp.wire_transport(Box::new(TcpTransport)),
        Some(other) => unreachable!("{other}"),
    };
    exp.run().unwrap()
}

/// The error-compensated partial-aggregation contract: one seeded kill
/// under `drop-round`, and the simulated engine, the threaded loopback
/// engine, and real TCP sockets produce the *same* trajectory — curve,
/// accounted bits, steps — while the server outlives the death.
#[test]
fn drop_round_pins_sim_loopback_and_tcp_bit_for_bit() {
    // The plan really schedules a death inside the run (rounds = 24).
    let deaths = FaultSpec::parse(DROP_PLAN)
        .unwrap()
        .unwrap()
        .plan(4, 24)
        .unwrap()
        .sim_deaths(4)
        .unwrap();
    assert_eq!(deaths.iter().flatten().count(), 1, "plan must kill exactly one node");

    let sim = drop_round_run(None);
    for transport in ["loopback", "tcp"] {
        let wire = drop_round_run(Some(transport));
        assert_eq!(sim.curve, wire.curve, "[{transport}] loss curve diverged");
        assert_eq!(sim.total_bits, wire.total_bits, "[{transport}] total_bits");
        assert_eq!(sim.steps, wire.steps, "[{transport}] steps");
        assert_eq!(sim.method, wire.method, "[{transport}] method");
    }

    // And the death left a mark: the degraded trajectory differs from
    // the fault-free one (same seed, full quorum).
    let data = experiments::dataset(Which::parse("epsilon").unwrap(), 100_000, DROP_SEED);
    let healthy = Experiment::new(LogisticModel::new(&data, 1.0 / data.n() as f64))
        .dataset(&data.name)
        .method(MethodSpec::parse("memsgd:top_k:1").unwrap())
        .schedule(Schedule::constant(0.1))
        .topology(Topology::ParamServerSync { nodes: 4 })
        .steps(96)
        .eval_points(3)
        .seed(DROP_SEED)
        .run()
        .unwrap();
    assert_ne!(healthy.curve, sim.curve, "the injected kill changed nothing");
}

/// 16 workers, three seeded deaths, quorum 4: the threaded wire engine
/// must terminate under a watchdog (every worker thread joined inside
/// the engine), keep the model finite, and keep serving the survivors.
#[test]
fn stress_16_workers_survive_three_drop_round_deaths() {
    let (tx, rx) = mpsc::channel();
    let handle = thread::spawn(move || {
        let data = experiments::dataset(Which::parse("epsilon").unwrap(), 100_000, 5);
        let rec = Experiment::new(LogisticModel::new(&data, 1.0 / data.n() as f64))
            .dataset(&data.name)
            .method(MethodSpec::parse("memsgd:top_k:1").unwrap())
            .schedule(Schedule::constant(0.1))
            .topology(Topology::ParamServerSync { nodes: 16 })
            .steps(16 * 24)
            .eval_points(3)
            .seed(5)
            .failure_policy(FailurePolicy::DropRound { min_quorum: 4 })
            .fault_plan(FaultSpec::parse("kill:3:909").unwrap().unwrap())
            .wire(true)
            .run();
        tx.send(rec).ok();
    });
    let rec = rx
        .recv_timeout(Duration::from_secs(120))
        .expect("16-worker drop-round run hung past the watchdog")
        .unwrap();
    handle.join().unwrap();
    assert!(rec.final_loss().is_finite(), "degraded model went non-finite");
    assert!(rec.curve.iter().all(|p| p.loss.is_finite()));
    assert!(rec.total_bits > 0);
}

// ---------------------------------------------------------------------------
// Cluster runtime: retries, rejoin, restart
// ---------------------------------------------------------------------------

fn chaos_config(nodes: usize, steps: usize) -> RunConfig {
    RunConfig {
        dataset: "epsilon".into(),
        scale: 100_000,
        seed: 11,
        method: "memsgd:top_k:1".into(),
        schedule: Schedule::constant(0.1),
        steps,
        eval_points: 3,
        nodes,
        local: LocalUpdate::default(),
        topology: "ps-sync".into(),
        network: "1g".into(),
        dim: 2000,
        failure_policy: FailurePolicy::FailFast,
        fault_plan: None,
        start_round: 0,
    }
}

fn backends() -> Vec<IoBackend> {
    if cfg!(unix) {
        vec![IoBackend::Poll, IoBackend::Threads]
    } else {
        vec![IoBackend::Threads]
    }
}

fn patient_backoff() -> Backoff {
    Backoff {
        attempts: 60,
        base: Duration::from_millis(5),
        cap: Duration::from_millis(50),
    }
}

/// The `--retries` bound must cover the *handshake*, not just the TCP
/// connect: a worker launched before its server exists keeps retrying
/// (connection refused, then possibly a reset mid-handshake) and
/// converges once the server binds.
#[test]
fn worker_dialing_before_the_server_binds_retries_through_the_handshake() {
    // Reserve a port, free it, and dial it before anything listens.
    let addr = {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.local_addr().unwrap().to_string()
    };
    let worker_addr = addr.clone();
    let worker = thread::spawn(move || {
        run_worker(&worker_addr, &Hello::any(), &patient_backoff(), false, None)
    });
    thread::sleep(Duration::from_millis(150));
    let server =
        ClusterServer::bind_with_io(&addr, chaos_config(1, 48), IoBackend::platform_default())
            .unwrap();
    let rec = server.run().unwrap();
    let (node, bits) = worker.join().unwrap().expect("worker must win via retries");
    assert_eq!(node, 0);
    assert!(bits > 0, "worker uploaded nothing");
    assert!(rec.final_loss().is_finite());
}

/// `wait-rejoin` end to end on both I/O backends: the server-side fault
/// plan kills one worker mid-run, the server holds the round open, a
/// replacement dials with `--resume`, is re-synced from a model
/// `SNAPSHOT` into the dead node's slot, and the run completes.
#[test]
fn wait_rejoin_resyncs_a_replacement_worker_from_a_snapshot() {
    let nodes = 2;
    let steps = 96; // rounds = 48
    let spec = FaultSpec::parse("kill:1:13").unwrap().unwrap();
    let deaths = spec.plan(nodes, 48).unwrap().sim_deaths(nodes).unwrap();
    let victim = deaths.iter().position(|d| d.is_some()).expect("plan kills someone");

    for io in backends() {
        let label = io.name();
        let mut cfg = chaos_config(nodes, steps);
        cfg.failure_policy = FailurePolicy::WaitRejoin { timeout: Duration::from_secs(60) };
        cfg.fault_plan = Some(spec.clone());

        let (tx, rx) = mpsc::channel();
        let handle = thread::spawn(move || {
            let server = ClusterServer::bind_with_io("127.0.0.1:0", cfg, io).unwrap();
            let addr = server.local_addr().unwrap().to_string();
            let server_handle = thread::spawn(move || server.run());
            let originals: Vec<_> = (0..nodes)
                .map(|_| {
                    let addr = addr.clone();
                    thread::spawn(move || {
                        run_worker(&addr, &Hello::any(), &patient_backoff(), false, None)
                    })
                })
                .collect();
            // The replacement dials immediately; its connection parks in
            // the listener backlog until the server reaches the death
            // round and starts accepting rejoiners.
            let rejoin_addr = addr.clone();
            let replacement = thread::spawn(move || {
                run_worker(&rejoin_addr, &Hello::any(), &patient_backoff(), true, None)
            });
            let record = server_handle.join().unwrap();
            let original_results: Vec<_> =
                originals.into_iter().map(|w| w.join().unwrap()).collect();
            let replacement_result = replacement.join().unwrap();
            tx.send((record, original_results, replacement_result)).ok();
        });
        let (record, original_results, replacement_result) = rx
            .recv_timeout(Duration::from_secs(120))
            .unwrap_or_else(|_| panic!("[{label}] wait-rejoin cluster hung past the watchdog"));
        handle.join().unwrap();

        let record = record.unwrap_or_else(|e| panic!("[{label}] server failed: {e:#}"));
        assert!(record.final_loss().is_finite(), "[{label}]");
        assert_eq!(record.steps, steps, "[{label}]");

        // Exactly the victim's original process died; the survivor and
        // the replacement both completed, the replacement in the
        // victim's slot.
        let failed: Vec<usize> = original_results
            .iter()
            .enumerate()
            .filter(|(_, r)| r.is_err())
            .map(|(i, _)| i)
            .collect();
        assert_eq!(failed.len(), 1, "[{label}] exactly one original worker must die");
        let (rejoined_node, _) = replacement_result
            .unwrap_or_else(|e| panic!("[{label}] replacement failed: {e:#}"));
        assert_eq!(rejoined_node, victim, "[{label}] replacement landed in the wrong slot");
    }
}

/// A checkpointed server restart: phase 1 runs to completion writing
/// cluster checkpoints; phase 2 reuses the file with a longer budget,
/// resumes at the recorded round (`start_round` > 0), re-syncs fresh
/// workers from the opening `SNAPSHOT`, and completes.
#[test]
fn checkpointed_server_restart_resumes_mid_run_and_completes() {
    let dir = std::env::temp_dir().join(format!("memsgd_chaos_ck_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cluster.ck");
    let _ = std::fs::remove_file(&path);

    let run_cluster = |cfg: RunConfig, path: std::path::PathBuf, expect_start: usize| {
        let nodes = cfg.nodes;
        let server =
            ClusterServer::bind_with_io("127.0.0.1:0", cfg, IoBackend::platform_default())
                .unwrap()
                .with_checkpoint(path, 1)
                .unwrap();
        assert_eq!(server.start_round(), expect_start);
        let addr = server.local_addr().unwrap().to_string();
        let server_handle = thread::spawn(move || server.run());
        let workers: Vec<_> = (0..nodes)
            .map(|_| {
                let addr = addr.clone();
                thread::spawn(move || {
                    run_worker(&addr, &Hello::any(), &patient_backoff(), false, None)
                })
            })
            .collect();
        let record = server_handle.join().unwrap().unwrap();
        let stats: Vec<(usize, u64)> =
            workers.into_iter().map(|w| w.join().unwrap().unwrap()).collect();
        (record, stats)
    };

    // Phase 1: 24 rounds, checkpoint every round — the file ends at 24.
    let (rec1, _) = run_cluster(chaos_config(2, 48), path.clone(), 0);
    assert!(rec1.final_loss().is_finite());
    assert!(path.exists(), "no cluster checkpoint written");

    // Phase 2: same shape, doubled budget (48 rounds). The restart must
    // pick up at round 24 and serve only the remainder; fresh workers
    // (no --resume flag needed — the WELCOME carries start_round) seed
    // their replicas from the opening SNAPSHOT.
    let (rec2, stats) = run_cluster(chaos_config(2, 96), path.clone(), 24);
    assert!(rec2.final_loss().is_finite());
    assert_eq!(rec2.steps, 96);
    let mut ids: Vec<usize> = stats.iter().map(|&(n, _)| n).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1]);

    let _ = std::fs::remove_dir_all(&dir);
}
