//! Golden-trajectory suite for the **sparse gradient pipeline**:
//!
//! * dense ↔ sparse trajectory equality — the engines must produce
//!   **bit-identical** runs whether the backend emits gradients densely
//!   or sparsely, on every `Topology × MethodSpec × LocalUpdate`
//!   combination (run under both `cargo test` and `cargo test
//!   --release`; CI exercises both profiles). For the active-scan
//!   compressors (top-k, threshold) the sparse side runs the
//!   **dimension-free active-set route** — gen-stamped accumulator,
//!   in-place local steps, `ErrorFeedbackStep::sync_active` — so this
//!   suite pins that whole path against the dense reference, including
//!   long runs where the active set saturates toward d,
//! * exactness of the capability gate (`λ = 0` opts in, `λ ≠ 0` falls
//!   back dense),
//! * allocation discipline: the sparse phase buffers stop growing after
//!   warm-up (same protocol as
//!   `top_k.rs::reuses_buffers_without_allocation_growth`).
//!
//! The dense run uses [`DenseShadow`], a wrapper that hides the
//! backend's sparse capability so the engines take the historical dense
//! path over the *same* data, seed, and schedule.

use memsgd::compress::{CompressorSpec, SparseVec};
use memsgd::coordinator::{Experiment, LocalUpdate, MethodSpec, Topology};
use memsgd::data::synthetic;
use memsgd::metrics::RunRecord;
use memsgd::models::{GradBackend, LeastSquaresModel, LogisticModel};
use memsgd::optim::{ErrorFeedbackStep, Schedule};
use memsgd::sim::network::NetworkModel;
use memsgd::util::prng::Prng;

const STEPS: usize = 240;
const ETA: f64 = 0.1;
const SEED: u64 = 17;

/// RCV1-like CSR data; `λ = 0` keeps per-sample gradients truly sparse.
fn data() -> memsgd::data::Dataset {
    synthetic::rcv1_like(160, 48, 0.15, 13)
}

/// Forwards a sparse-capable backend but reports itself dense, forcing
/// the engines onto the dense path for the equality comparison.
#[derive(Clone)]
struct DenseShadow<B: GradBackend>(B);

impl<B: GradBackend> GradBackend for DenseShadow<B> {
    fn dim(&self) -> usize {
        self.0.dim()
    }
    fn n(&self) -> usize {
        self.0.n()
    }
    fn sample_grad(&mut self, x: &[f32], i: usize, out: &mut [f32]) {
        self.0.sample_grad(x, i, out);
    }
    fn sample_grad_batch(&mut self, x: &[f32], idx: &[usize], out: &mut [f32]) {
        self.0.sample_grad_batch(x, idx, out);
    }
    fn full_loss(&mut self, x: &[f32]) -> f64 {
        self.0.full_loss(x)
    }
    // supports_sparse_grad intentionally NOT forwarded: default false.
}

fn all_methods() -> Vec<MethodSpec> {
    vec![
        MethodSpec::mem_top_k(2),
        MethodSpec::mem_rand_k(2),
        MethodSpec::mem(CompressorSpec::RandomP { p: 0.5 }),
        MethodSpec::mem(CompressorSpec::Sign),
        MethodSpec::mem(CompressorSpec::Threshold { tau: 0.25 }),
        MethodSpec::mem(CompressorSpec::Identity),
        MethodSpec::Sgd,
        MethodSpec::SgdQsgd { levels: 16, eff: None },
        MethodSpec::SgdUnbiasedRandK { k: 2 },
    ]
}

fn all_topologies() -> Vec<Topology> {
    vec![
        // One shared-memory worker: >1 lock-free threads are racy by
        // design and not reproducible run-to-run; the parameter-server
        // engines are simulated in-process, so multi-node stays exact.
        Topology::Sequential,
        Topology::SharedMemory { workers: 1 },
        Topology::ParamServerSync { nodes: 3 },
        Topology::ParamServerAsync { nodes: 3, net: NetworkModel::eth_10g() },
    ]
}

fn all_locals() -> Vec<LocalUpdate> {
    vec![
        LocalUpdate::default(),          // B = 1, H = 1 (fast path)
        LocalUpdate::new(1, 4).unwrap(), // pure local steps
        LocalUpdate::new(4, 1).unwrap(), // pure minibatching
        LocalUpdate::new(4, 3).unwrap(), // both
    ]
}

fn run_steps<B: GradBackend + Clone + Send>(
    backend: B,
    method: &MethodSpec,
    topology: &Topology,
    local: LocalUpdate,
    steps: usize,
) -> RunRecord {
    Experiment::new(backend)
        .method(method.clone())
        .schedule(Schedule::constant(ETA))
        .topology(topology.clone())
        .steps(steps)
        .eval_points(4)
        .average(false)
        .seed(SEED)
        .local_update(local)
        .run()
        .unwrap()
}

fn run<B: GradBackend + Clone + Send>(
    backend: B,
    method: &MethodSpec,
    topology: &Topology,
    local: LocalUpdate,
) -> RunRecord {
    run_steps(backend, method, topology, local, STEPS)
}

fn assert_identical(dense: &RunRecord, sparse: &RunRecord, what: &str) {
    assert_eq!(dense.steps, sparse.steps, "{what}: steps");
    assert_eq!(dense.total_bits, sparse.total_bits, "{what}: bits");
    // LossPoint is PartialEq over (t, bits, loss): whole-curve equality
    // pins every evaluated iterate bit for bit (identical x ⇒ identical
    // f64 loss; a single diverging f32 anywhere shows up here).
    assert_eq!(dense.curve, sparse.curve, "{what}: loss curve");
}

#[test]
fn dense_and_sparse_trajectories_are_bit_identical_everywhere() {
    let ds = data();
    for method in all_methods() {
        for topology in all_topologies() {
            for local in all_locals() {
                let what = format!("{} x {topology:?} x {local:?}", method.name());
                let sparse_backend = LogisticModel::new(&ds, 0.0);
                assert!(sparse_backend.supports_sparse_grad(), "{what}");
                let rec_sparse = run(sparse_backend, &method, &topology, local);
                let rec_dense =
                    run(DenseShadow(LogisticModel::new(&ds, 0.0)), &method, &topology, local);
                assert_identical(&rec_dense, &rec_sparse, &what);
            }
        }
    }
}

#[test]
fn active_set_saturation_stays_bit_identical() {
    // Long top-1 runs on dense-ish rows: the residual's support (the
    // active set the O(touched) sync path tracks) grows toward d and the
    // per-phase touched set covers most coordinates — the regime where
    // the active path degenerates to ~O(d) work and any bookkeeping slip
    // (stale support, wrong zero-padding, tie drift) would surface.
    // Trajectories must still match the forced-dense route bit for bit.
    let ds = synthetic::rcv1_like(160, 48, 0.35, 29);
    let method = MethodSpec::mem_top_k(1);
    let local = LocalUpdate::new(2, 4).unwrap();
    let steps = 1_600;
    for topology in [Topology::Sequential, Topology::ParamServerSync { nodes: 3 }] {
        let what = format!("saturation x {topology:?}");
        let sparse_backend = LogisticModel::new(&ds, 0.0);
        assert!(sparse_backend.supports_sparse_grad(), "{what}");
        let rec_sparse = run_steps(sparse_backend, &method, &topology, local, steps);
        let rec_dense = run_steps(
            DenseShadow(LogisticModel::new(&ds, 0.0)),
            &method,
            &topology,
            local,
            steps,
        );
        assert_identical(&rec_dense, &rec_sparse, &what);
    }
}

#[test]
fn least_squares_backend_matches_too() {
    // The second native model goes through the same pipeline: one
    // representative schedule per topology keeps the runtime modest.
    let ds = data();
    let method = MethodSpec::mem_top_k(2);
    let local = LocalUpdate::new(2, 3).unwrap();
    for topology in all_topologies() {
        let what = format!("least-squares x {topology:?}");
        let sparse_backend = LeastSquaresModel::new(&ds, 0.0);
        assert!(sparse_backend.supports_sparse_grad(), "{what}");
        let rec_sparse = run(sparse_backend, &method, &topology, local);
        let rec_dense =
            run(DenseShadow(LeastSquaresModel::new(&ds, 0.0)), &method, &topology, local);
        assert_identical(&rec_dense, &rec_sparse, &what);
    }
}

#[test]
fn regularized_models_fall_back_to_the_dense_path() {
    let ds = data();
    assert!(!LogisticModel::with_paper_lambda(&ds).supports_sparse_grad());
    assert!(!LeastSquaresModel::new(&ds, 0.05).supports_sparse_grad());
    // And the λ ≠ 0 trajectory is untouched by the pipeline's existence:
    // wrapping in DenseShadow (pure delegation) changes nothing.
    let method = MethodSpec::mem_top_k(2);
    let local = LocalUpdate::new(2, 2).unwrap();
    let a = run(LogisticModel::with_paper_lambda(&ds), &method, &Topology::Sequential, local);
    let b = run(
        DenseShadow(LogisticModel::with_paper_lambda(&ds)),
        &method,
        &Topology::Sequential,
        local,
    );
    assert_identical(&a, &b, "lam != 0");
}

#[test]
fn sparse_emission_values_match_dense_gradients_exactly() {
    // Unit-level spot check at the integration boundary: the emitted
    // sparse values are the dense gradient's nonzeros, bit for bit,
    // including merged duplicate samples.
    let ds = data();
    let mut m = LogisticModel::new(&ds, 0.0);
    let d = ds.d();
    let mut rng = Prng::new(3);
    let x: Vec<f32> = (0..d).map(|_| 0.2 * rng.normal_f32()).collect();
    let mut dense = vec![0.0f32; d];
    let mut sparse = SparseVec::new(d);
    for i in 0..ds.n() {
        m.sample_grad(&x, i, &mut dense);
        m.sample_grad_sparse(&x, i, &mut sparse);
        assert_eq!(sparse.to_dense(), dense, "sample {i}");
    }
    let idx = [7usize, 99, 7, 42, 99, 0];
    m.sample_grad_batch(&x, &idx, &mut dense);
    m.sample_grad_batch_sparse(&x, &idx, &mut sparse);
    assert_eq!(sparse.to_dense(), dense, "merged batch");
}

#[test]
fn sparse_phase_buffers_stop_growing_after_warmup() {
    // Same protocol as top_k.rs::reuses_buffers_without_allocation_growth,
    // across the full sparse step: emission buffer and the compressed
    // update inside ErrorFeedbackStep must reuse their allocations.
    let ds = data();
    let mut m = LogisticModel::new(&ds, 0.0);
    let d = ds.d();
    let mut ef = ErrorFeedbackStep::new(d, CompressorSpec::TopK { k: 2 }.build());
    let mut rng = Prng::new(4);
    let x = vec![0.05f32; d];
    let mut sgrad = SparseVec::new(d);

    let mut one_step = |m: &mut LogisticModel, ef: &mut ErrorFeedbackStep, rng: &mut Prng| {
        let idx: Vec<usize> = (0..8).map(|_| rng.below(ds.n())).collect();
        m.sample_grad_batch_sparse(&x, &idx, &mut sgrad);
        ef.step_sparse(&sgrad, 0.1, rng);
        let update_cap = match ef.update() {
            memsgd::compress::Update::Sparse(s) => (s.idx.capacity(), s.val.capacity()),
            memsgd::compress::Update::Dense(g) => (g.capacity(), 0),
        };
        (sgrad.idx.capacity(), sgrad.val.capacity(), update_cap)
    };

    let warm = one_step(&mut m, &mut ef, &mut rng);
    for round in 0..200 {
        let caps = one_step(&mut m, &mut ef, &mut rng);
        assert_eq!(caps, warm, "round {round}: phase buffers grew after warm-up");
    }
}

#[test]
fn sparse_runs_report_the_same_schedule_metadata() {
    // The pipeline must not disturb the record surface: extras like
    // batch/sync_every/grad_samples stay identical.
    let ds = data();
    let local = LocalUpdate::new(4, 3).unwrap();
    let rec = run(
        LogisticModel::new(&ds, 0.0),
        &MethodSpec::mem_top_k(1),
        &Topology::Sequential,
        local,
    );
    assert_eq!(rec.extra["batch"], 4.0);
    assert_eq!(rec.extra["sync_every"], 3.0);
    assert_eq!(rec.extra["grad_samples"], rec.steps as f64 * 4.0);
}
