//! Failure-injection suite: the coordinator must degrade loudly (errors)
//! or safely (finite, bounded state) under hostile inputs — non-finite
//! gradients, malformed data files, corrupted checkpoints, absurd
//! configurations, and peers vanishing mid-protocol on the server-free
//! wire engines.

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use anyhow::Result;
use memsgd::compress::{self, Update};
use memsgd::coordinator::checkpoint::Checkpoint;
use memsgd::coordinator::faults::FaultyTransport;
use memsgd::coordinator::train::{self, TrainConfig};
use memsgd::coordinator::transport::Loopback;
use memsgd::coordinator::{Experiment, FaultPlan, GossipGraph, MethodSpec, Topology};
use memsgd::data::{libsvm, synthetic, Dataset};
use memsgd::models::{GradBackend, LogisticModel};
use memsgd::optim::{MemSgd, Schedule};
use memsgd::util::prng::Prng;

// ---------------------------------------------------------------------------
// Non-finite gradients
// ---------------------------------------------------------------------------

/// A NaN gradient must not corrupt coordinates the update does not touch:
/// top-k propagates at most k poisoned coordinates per step, and the
/// error memory quarantines the rest.
#[test]
fn nan_gradient_poisons_at_most_k_coordinates_per_step() {
    let d = 32;
    let mut opt = MemSgd::new(vec![1.0f32; d], compress::from_spec("top_k:2").unwrap());
    let mut rng = Prng::new(1);
    let mut grad = vec![0.5f32; d];
    grad[3] = f32::NAN;
    opt.step(&grad, 0.1, &mut rng);
    let poisoned_x = opt.x.iter().filter(|v| !v.is_finite()).count();
    // NaN sorts unpredictably through the selector, but the applied
    // update has at most 2 entries.
    assert!(poisoned_x <= 2, "{poisoned_x} poisoned coords in x");
}

/// Vanilla (identity) transmission spreads the NaN everywhere — the
/// contrast that makes the sparse path auditable.
#[test]
fn infinite_gradient_detected_in_memory_norm() {
    let d = 16;
    let mut opt = MemSgd::new(vec![0.0f32; d], compress::from_spec("top_k:1").unwrap());
    let mut rng = Prng::new(2);
    let mut grad = vec![1.0f32; d];
    grad[5] = f32::INFINITY;
    opt.step(&grad, 0.1, &mut rng);
    // The monitoring hook every driver exposes: ‖m‖² goes non-finite,
    // which is the signal a production loop would alarm on.
    assert!(
        !opt.memory_norm_sq().is_finite() || !opt.x.iter().all(|v| v.is_finite()),
        "an infinite gradient must be visible in x or m"
    );
}

// ---------------------------------------------------------------------------
// Malformed LIBSVM input
// ---------------------------------------------------------------------------

#[test]
fn libsvm_rejects_garbage_lines() {
    for text in [
        "+1 3:abc\n",        // non-numeric value
        "+1 0:1.0\n",        // LIBSVM indices are 1-based
        "+1 5\n",            // missing colon
        "maybe 1:2.0\n",     // unparsable label
    ] {
        assert!(
            libsvm::parse(text.as_bytes(), None, "t".into()).is_err(),
            "accepted garbage: {text:?}"
        );
    }
}

#[test]
fn libsvm_accepts_blank_and_comment_lines() {
    let text = "# comment\n\n+1 1:0.5 4:1.5\n-1 2:2.0\n";
    let ds = libsvm::parse(text.as_bytes(), None, "t".into()).unwrap();
    assert_eq!(ds.n(), 2);
    assert_eq!(ds.d(), 4);
}

#[test]
fn libsvm_missing_file_errors_cleanly() {
    let err = libsvm::load("/nonexistent/path/data.svm", None).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("nonexistent"), "unhelpful error: {msg}");
}

// ---------------------------------------------------------------------------
// Corrupted checkpoints
// ---------------------------------------------------------------------------

#[test]
fn checkpoint_bitflips_never_panic() {
    let mut opt = MemSgd::new(vec![0.3f32; 24], compress::from_spec("top_k:1").unwrap());
    let mut rng = Prng::new(3);
    let grad = vec![0.1f32; 24];
    for _ in 0..10 {
        opt.step(&grad, 0.1, &mut rng);
    }
    let bytes = Checkpoint::capture(&opt, "top_k:1", &rng, None).to_bytes();
    // Flip every byte position in turn; parsing must either succeed (the
    // flip hit payload data) or error — never panic or hang.
    for pos in 0..bytes.len().min(256) {
        let mut corrupted = bytes.clone();
        corrupted[pos] ^= 0xA5;
        let _ = Checkpoint::from_bytes(&corrupted);
    }
    // Truncations at every length too.
    for len in 0..bytes.len().min(128) {
        let _ = Checkpoint::from_bytes(&bytes[..len]);
    }
}

#[test]
fn checkpoint_with_hostile_spec_fails_on_restore_not_capture() {
    let mut ck = {
        let opt = MemSgd::new(vec![0.0f32; 4], compress::from_spec("top_k:1").unwrap());
        let rng = Prng::new(4);
        Checkpoint::capture(&opt, "top_k:1", &rng, None)
    };
    ck.compressor_spec = "definitely_not_a_compressor:9".into();
    let bytes = ck.to_bytes();
    let back = Checkpoint::from_bytes(&bytes).unwrap(); // parse is fine
    assert!(back.restore().is_err(), "hostile spec must fail restore");
}

// ---------------------------------------------------------------------------
// Hostile configurations
// ---------------------------------------------------------------------------

#[test]
fn train_rejects_unknown_method() {
    let data = synthetic::epsilon_like(64, 8, 1);
    let cfg = TrainConfig {
        method: "adamw:top_k:1".into(),
        steps: 10,
        ..TrainConfig::default()
    };
    assert!(train::run(&data, &cfg).is_err());
}

#[test]
fn zero_steps_run_returns_initial_loss_only() {
    let data = synthetic::epsilon_like(64, 8, 1);
    let cfg = TrainConfig {
        method: "memsgd:top_k:1".into(),
        steps: 0,
        ..TrainConfig::default()
    };
    let rec = train::run(&data, &cfg).unwrap();
    assert_eq!(rec.steps, 0);
    assert!((rec.final_loss() - (2.0f64).ln()).abs() < 1e-6); // f(0) = ln 2
}

#[test]
fn k_larger_than_dimension_behaves_like_dense() {
    let data = synthetic::epsilon_like(128, 8, 2);
    let run_with = |method: &str| {
        let cfg = TrainConfig {
            method: method.into(),
            steps: 400,
            schedule: Schedule::constant(0.3),
            seed: 9,
            ..TrainConfig::default()
        };
        train::run(&data, &cfg).unwrap().final_loss()
    };
    let huge_k = run_with("memsgd:top_k:100"); // k > d = 8
    let dense = run_with("sgd");
    assert!(
        (huge_k - dense).abs() < 0.05,
        "top_k with k>d should track dense: {huge_k} vs {dense}"
    );
}

#[test]
fn schedule_rejects_bad_parameters() {
    // Constructor contracts: invalid schedules must be unrepresentable.
    assert!(std::panic::catch_unwind(|| Schedule::inv_t(2.0, 0.0, 10.0)).is_err());
    assert!(std::panic::catch_unwind(|| Schedule::constant(-0.1)).is_err());
}

// ---------------------------------------------------------------------------
// Dataset degeneracies
// ---------------------------------------------------------------------------

#[test]
fn single_example_dataset_trains() {
    let data = Dataset::dense("one", vec![1.0, -1.0], 2, vec![1.0]);
    let cfg = TrainConfig {
        method: "memsgd:top_k:1".into(),
        steps: 200,
        schedule: Schedule::constant(0.5),
        ..TrainConfig::default()
    };
    let rec = train::run(&data, &cfg).unwrap();
    assert!(rec.final_loss().is_finite());
    assert!(rec.final_loss() < (2.0f64).ln()); // made progress
}

#[test]
fn all_same_label_dataset_is_separable_and_converges() {
    let mut model_data = Vec::new();
    for i in 0..32 {
        model_data.extend_from_slice(&[1.0 + (i % 3) as f32 * 0.1, 0.5]);
    }
    let data = Dataset::dense("same", model_data, 2, vec![1.0; 32]);
    let mut model = LogisticModel::new(&data, 1e-4);
    let mut opt = MemSgd::new(vec![0.0f32; 2], compress::from_spec("top_k:1").unwrap());
    let mut rng = Prng::new(5);
    let mut grad = vec![0.0f32; 2];
    for _ in 0..2_000 {
        let i = rng.below(data.n());
        model.sample_grad(&opt.x, i, &mut grad);
        opt.step(&grad, 0.5, &mut rng);
    }
    assert!(model.full_loss(&opt.x) < 0.3);
}

// ---------------------------------------------------------------------------
// Server-free wire engines: peers vanishing mid-protocol
// ---------------------------------------------------------------------------

/// Run an experiment with one cut link under a watchdog (the transport
/// is built inside the watchdog thread — `dyn Transport` is not
/// `Send`). The cut is injected by the promoted coordinator fault
/// machinery: [`FaultyTransport`] wraps the `target`-th duplex's
/// server/observer end with a [`FaultPlan::cut_send`] that hangs up on
/// the `sends`-th (0-indexed) send and drops the wrapped endpoint, so
/// the peer's blocked `recv` observes a genuine close. Duplex creation
/// order is part of the engines' documented contracts (ring: directed
/// edge `i → (i+1) % n` in edge order; gossip: edges `(a, b)` for
/// `a < b` in lexicographic order, then one monitor per node), so the
/// target index selects exactly one known link. The engines' teardown
/// contract is that an error anywhere cascades as "channel closed"
/// around the fabric (every endpoint dropped on the error path), so a
/// dead peer can never hang the run; `thread::scope` inside the engine
/// guarantees every node thread is joined before the error returns.
fn run_with_watchdog(
    topology: Topology,
    target: usize,
    sends: u64,
) -> Result<memsgd::metrics::RunRecord> {
    let (tx, rx) = mpsc::channel();
    let handle = thread::spawn(move || {
        let transport =
            FaultyTransport::new(Box::new(Loopback), FaultPlan::cut_send(target, sends));
        let data = synthetic::epsilon_like(240, 12, 5);
        let result = Experiment::new(LogisticModel::new(&data, 1.0 / 240.0))
            .dataset(&data.name)
            .method(MethodSpec::mem_top_k(2))
            .schedule(Schedule::constant(0.4))
            .topology(topology)
            .steps(150)
            .eval_points(3)
            .seed(7)
            .wire_transport(Box::new(transport))
            .run();
        tx.send(result).ok();
    });
    let result = rx
        .recv_timeout(Duration::from_secs(60))
        .expect("wire engine hung past the watchdog with a dead peer");
    handle.join().unwrap();
    result
}

/// A ring peer closing mid-reduce: node 2's ring edge to the driver is
/// cut after two rounds, so its third GATHER send fails and every other
/// node observes a closed channel. The run must fail descriptively —
/// the error names the node the driver lost — and must return (no hung
/// recv, all threads joined).
#[test]
fn ring_peer_closing_mid_reduce_fails_descriptively_without_hanging() {
    // Duplex order is edge order: 0→1, 1→2, 2→0. Target index 2 cuts
    // the 2→0 edge whose server (sending) end node 2 holds.
    let err = run_with_watchdog(Topology::AllReduce { nodes: 3 }, 2, 2)
        .expect_err("a cut ring edge must fail the run");
    let msg = format!("{err:#}");
    assert!(msg.contains("node 2"), "error does not name the lost node: {msg}");
    assert!(
        msg.contains("channel closed") || msg.contains("injected fault"),
        "error misses the disconnect: {msg}"
    );
}

/// A gossip neighbor dropping mid-exchange: node 0's edge to node 1 is
/// cut after two paired exchanges, so node 0 dies mid-exchange and the
/// recording driver finds its next REPORT missing. The run must fail
/// descriptively with the dead node named, and must return.
#[test]
fn gossip_neighbor_dropping_mid_exchange_fails_descriptively_without_hanging() {
    // Duplex order: edges (0,1), (0,2), (1,2), then monitors 0, 1, 2.
    // Target index 0 cuts the (0,1) edge whose server (lower-id) end
    // node 0 holds.
    let err =
        run_with_watchdog(Topology::Gossip { nodes: 3, graph: GossipGraph::Complete }, 0, 2)
            .expect_err("a cut gossip edge must fail the run");
    let msg = format!("{err:#}");
    assert!(msg.contains("node 0"), "error does not name the dead node: {msg}");
    assert!(
        msg.contains("channel closed") || msg.contains("injected fault"),
        "error misses the disconnect: {msg}"
    );
}

/// Sparse updates applied to the wrong-dimension vector are a programmer
/// error; in release builds SparseVec::sub_from on a larger x must not
/// write out of bounds of the declared dim (indices are validated at
/// construction).
#[test]
fn sparse_update_indices_always_in_bounds() {
    let mut rng = Prng::new(6);
    for _ in 0..200 {
        let d = 1 + rng.below(100);
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut comp = compress::from_spec("top_k:3").unwrap();
        let mut out = Update::new_sparse(d);
        comp.compress(&x, &mut rng, &mut out);
        if let Update::Sparse(s) = &out {
            assert!(s.idx.iter().all(|&i| (i as usize) < d));
        }
    }
}
