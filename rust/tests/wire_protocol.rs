//! Wire-equivalence suite: the threaded parameter-server engines
//! against their single-threaded simulations, and the payload codec
//! against the paper's bit accounting.
//!
//! * **Golden trajectories** — `ParamServerSync` and `ParamServerAsync`
//!   in wire mode must reproduce the simulated engines **bit for bit**
//!   (loss curve, accounted bits, every extra the simulation reports)
//!   on every `MethodSpec` × `LocalUpdate` combination and on **every
//!   transport backend** (in-process `Loopback` and kernel-socket
//!   `TcpTransport`), with every update round-tripping through the
//!   Elias payload codec and a real channel between threads.
//! * **Wire accounting** — the `wire_frame_bits` a run reports must
//!   equal the bytes independently counted at the channel boundary
//!   (`CountingTransport`), i.e. reported bits are transmitted bytes —
//!   split per direction: `wire_upload_frame_bits` is worker→server
//!   bytes, `wire_broadcast_frame_bits` server→worker bytes.
//! * **Codec reconciliation** — for every `CompressorSpec`, the framed
//!   payload decodes back to the exact update and its measured length
//!   matches an independent closed-form recomputation; where the
//!   paper's accounting is an Appendix-B *estimate* (QSGD) rather than
//!   a wire-exact count, the reconciliation is explicit (both
//!   quantities asserted, the measured one reported by the wire path).

use std::sync::atomic::Ordering;

use memsgd::compress::elias::{
    decode_payload, gamma_bits, BitReader, BitWriter, TAG_DENSE_RAW, TAG_SIGN, TAG_SPARSE,
};
use memsgd::compress::{sparse::index_bits, Compressor, CompressorSpec, Update};
use memsgd::coordinator::net::TcpTransport;
use memsgd::coordinator::transport::{CountingTransport, Loopback, Transport};
use memsgd::coordinator::{Experiment, LocalUpdate, MethodSpec, Topology};
use memsgd::data::Dataset;
use memsgd::metrics::RunRecord;
use memsgd::models::LogisticModel;
use memsgd::optim::Schedule;
use memsgd::sim::network::NetworkModel;
use memsgd::util::prng::Prng;

fn data() -> Dataset {
    memsgd::data::synthetic::epsilon_like(240, 12, 5)
}

/// Every method kind the engines accept: memory-carrying sparsifiers
/// (active-scan and dense-route), the data-dependent operators, the
/// composed quantization-∘-sparsification and adaptive operators, the
/// memory-free baselines, and the scaled unbiased estimator.
fn all_methods() -> Vec<MethodSpec> {
    [
        "memsgd:top_k:2",
        "memsgd:rand_k:2",
        "memsgd:random_p:0.5",
        "memsgd:block_top_k:3",
        "memsgd:sign",
        "memsgd:threshold:0.25",
        "memsgd:qsgd:8",
        "memsgd:qsgd:8(top_k:2)",
        "memsgd:adaptive:3",
        "sgd",
        "sgd:qsgd:8",
        "sgd:unbiased_rand_k:2",
    ]
    .iter()
    .map(|s| MethodSpec::parse(s).unwrap())
    .collect()
}

fn all_locals() -> Vec<LocalUpdate> {
    vec![LocalUpdate::default(), LocalUpdate::new(2, 3).unwrap()]
}

/// Every socket fabric the wire engines must be indistinguishable over:
/// the in-process loopback and real TCP sockets on localhost.
fn backends() -> Vec<(&'static str, fn() -> Box<dyn Transport>)> {
    vec![
        ("loopback", || Box::new(Loopback) as Box<dyn Transport>),
        ("tcp", || Box::new(TcpTransport) as Box<dyn Transport>),
    ]
}

/// Bit-for-bit record equality: curve (t, accounted bits, f64 loss),
/// step/bit totals, and every extra the simulated engine reports. The
/// wire record may add `wire_*` keys on top; nothing the simulation
/// wrote may differ.
fn assert_records_match(sim: &RunRecord, wired: &RunRecord, label: &str) {
    assert_eq!(sim.method, wired.method, "{label}: method");
    assert_eq!(sim.dataset, wired.dataset, "{label}: dataset");
    assert_eq!(sim.schedule, wired.schedule, "{label}: schedule");
    assert_eq!(sim.steps, wired.steps, "{label}: steps");
    assert_eq!(sim.total_bits, wired.total_bits, "{label}: total_bits");
    assert_eq!(sim.curve, wired.curve, "{label}: loss curve (t/bits/loss, bit-for-bit)");
    for (key, val) in &sim.extra {
        assert_eq!(
            wired.extra.get(key),
            Some(val),
            "{label}: extra[{key}] diverged"
        );
    }
    assert_eq!(wired.extra.get("wire"), Some(&1.0), "{label}: wire marker");
    assert!(wired.extra["wire_frame_bits"] > 0.0, "{label}: no frames counted");
    assert!(
        wired.extra["wire_upload_payload_bits"] > 0.0,
        "{label}: no upload payloads counted"
    );
    // The per-direction split must account for every frame bit.
    assert_eq!(
        wired.extra["wire_upload_frame_bits"] + wired.extra["wire_broadcast_frame_bits"],
        wired.extra["wire_frame_bits"],
        "{label}: per-direction frame bits don't sum to the total"
    );
}

#[test]
fn threaded_sync_engine_is_bit_identical_on_every_method_and_schedule() {
    let data = data();
    for method in all_methods() {
        for local in all_locals() {
            let label = format!("{} B={} H={}", method.name(), local.batch, local.sync_every);
            let run = |transport: Option<Box<dyn Transport>>| {
                let exp = Experiment::new(LogisticModel::new(&data, 1.0 / 240.0))
                    .dataset(&data.name)
                    .method(method.clone())
                    .schedule(Schedule::constant(0.4))
                    .topology(Topology::ParamServerSync { nodes: 3 })
                    .steps(540)
                    .eval_points(4)
                    .seed(7)
                    .local_update(local);
                match transport {
                    Some(t) => exp.wire_transport(t),
                    None => exp,
                }
                .run()
                .unwrap()
            };
            let sim = run(None);
            for (backend, make) in backends() {
                let wired = run(Some(make()));
                assert_records_match(&sim, &wired, &format!("{label} [{backend}]"));
            }
        }
    }
}

#[test]
fn threaded_async_engine_is_bit_identical_on_every_method_and_schedule() {
    let data = data();
    for method in all_methods() {
        for local in all_locals() {
            let label = format!(
                "async {} B={} H={}",
                method.name(),
                local.batch,
                local.sync_every
            );
            let run = |transport: Option<Box<dyn Transport>>| {
                let exp = Experiment::new(LogisticModel::new(&data, 1.0 / 240.0))
                    .dataset(&data.name)
                    .method(method.clone())
                    .schedule(Schedule::constant(0.4))
                    .topology(Topology::ParamServerAsync {
                        nodes: 3,
                        net: NetworkModel::eth_1g(),
                    })
                    .steps(240)
                    .eval_points(4)
                    .seed(7)
                    .local_update(local);
                match transport {
                    Some(t) => exp.wire_transport(t),
                    None => exp,
                }
                .run()
                .unwrap()
            };
            let sim = run(None);
            for (backend, make) in backends() {
                let wired = run(Some(make()));
                let label = format!("{label} [{backend}]");
                assert_records_match(&sim, &wired, &label);
                // The async-specific simulated-time results must
                // reproduce exactly too (already covered by the extras
                // sweep, but these are the reproducibility headline —
                // pin them by name).
                for key in
                    ["mean_staleness", "max_staleness", "sim_seconds", "link_utilization"]
                {
                    assert_eq!(sim.extra[key], wired.extra[key], "{label}: {key}");
                }
            }
        }
    }
}

#[test]
fn reported_wire_bits_equal_bytes_counted_on_the_channel() {
    let data = data();
    for (backend, make) in backends() {
        for (topology, steps) in [
            (Topology::ParamServerSync { nodes: 3 }, 540usize),
            (Topology::ParamServerAsync { nodes: 3, net: NetworkModel::eth_1g() }, 240),
        ] {
            let transport = CountingTransport::new(make());
            let counter = transport.counter();
            let up = transport.upload_counter();
            let down = transport.broadcast_counter();
            let rec = Experiment::new(LogisticModel::new(&data, 1.0 / 240.0))
                .dataset(&data.name)
                .method(MethodSpec::mem_top_k(2))
                .schedule(Schedule::constant(0.4))
                .topology(topology.clone())
                .steps(steps)
                .eval_points(4)
                .seed(3)
                .wire_transport(Box::new(transport))
                .run()
                .unwrap();
            let label = format!("{topology:?} [{backend}]");
            let counted_bits = counter.load(Ordering::Relaxed) * 8;
            let up_bits = up.load(Ordering::Relaxed) * 8;
            let down_bits = down.load(Ordering::Relaxed) * 8;
            assert_eq!(
                rec.extra["wire_frame_bits"], counted_bits as f64,
                "{label}: reported frame bits != bytes on the channel"
            );
            // The per-direction reconciliation: reported upload frame
            // bits are exactly the worker→server bytes, broadcast frame
            // bits exactly the server→worker bytes, and together they
            // are every byte the channel carried.
            assert_eq!(
                rec.extra["wire_upload_frame_bits"], up_bits as f64,
                "{label}: reported upload frame bits != worker->server bytes"
            );
            assert_eq!(
                rec.extra["wire_broadcast_frame_bits"], down_bits as f64,
                "{label}: reported broadcast frame bits != server->worker bytes"
            );
            assert_eq!(
                up_bits + down_bits,
                counted_bits,
                "{label}: direction split loses bytes"
            );
            // Payloads are a subset of the frames (headers + padding),
            // per direction too.
            assert!(
                rec.extra["wire_upload_payload_bits"] > 0.0,
                "{label}: no upload payload bits"
            );
            assert!(
                rec.extra["wire_upload_payload_bits"] <= up_bits as f64,
                "{label}: upload payload exceeds upload frames"
            );
            assert!(
                rec.extra["wire_broadcast_payload_bits"] <= down_bits as f64,
                "{label}: broadcast payload exceeds broadcast frames"
            );
        }
    }
}

fn update_bits(u: &Update, d: usize) -> Vec<u32> {
    u.to_dense(d).iter().map(|v| v.to_bits()).collect()
}

/// Sorted `(index, value-bits)` entries of a sparse update — includes
/// zero-valued padding coordinates, which `to_dense` is blind to but
/// which cost wire bits and occupy server aggregation slots.
fn sparse_entries(u: &Update) -> Option<Vec<(u32, u32)>> {
    match u {
        Update::Sparse(s) => {
            let mut e: Vec<(u32, u32)> =
                s.idx.iter().zip(&s.val).map(|(&i, &v)| (i, v.to_bits())).collect();
            e.sort_unstable();
            Some(e)
        }
        Update::Dense(_) => None,
    }
}

/// Independent recomputation of the framed sparse-payload size from the
/// update alone (via `gamma_bits`, not the encoder).
fn expected_sparse_payload_bits(u: &Update) -> u64 {
    let Update::Sparse(s) = u else { panic!("sparse update expected") };
    let mut idx: Vec<u32> = s.idx.clone();
    idx.sort_unstable();
    let mut bits = gamma_bits(TAG_SPARSE) + gamma_bits(s.nnz() as u64 + 1);
    let mut prev = 0u64;
    for (rank, &i) in idx.iter().enumerate() {
        let i = i as u64;
        let delta = if rank == 0 { i } else { i - prev - 1 };
        prev = i;
        bits += gamma_bits(delta + 1) + 32;
    }
    bits
}

#[test]
fn payload_codec_reconciles_accounted_bits_for_every_compressor_spec() {
    let d = 64usize;
    let specs = [
        "identity",
        "top_k:3",
        "rand_k:4",
        "random_p:0.9",
        "block_top_k:5",
        "sign",
        "threshold:0.25",
        "qsgd:8",
        "qsgd:8:32",
        "qsgd:8(top_k:3)",
        "adaptive:5",
    ];
    for spec in specs {
        let cspec = CompressorSpec::parse(spec).unwrap();
        let mut comp = cspec.build();
        let mut rng = Prng::new(99);
        let mut out = Update::new_sparse(d);
        let mut w = BitWriter::new();
        for t in 0..25usize {
            let x: Vec<f32> = (0..d)
                .map(|i| ((t * 13 + i * 7) % 29) as f32 / 29.0 - 0.48)
                .collect();
            let accounted = comp.compress(&x, &mut rng, &mut out);
            w.clear();
            let wire = comp.encode_payload(&out, &mut w);
            assert_eq!(w.bits(), wire, "{spec} t={t}: returned bits != written bits");

            // 1) The payload decodes back to the exact update.
            let mut r = BitReader::new(w.as_bytes());
            let back = decode_payload(&mut r, d).unwrap();
            assert_eq!(r.consumed(), wire, "{spec} t={t}: consumed != produced");
            assert_eq!(update_bits(&back, d), update_bits(&out, d), "{spec} t={t}: values");
            if let (Some(a), Some(b)) = (sparse_entries(&out), sparse_entries(&back)) {
                assert_eq!(a, b, "{spec} t={t}: sparse entry sets (incl. zero-valued padding)");
            }

            // 2) Accounted-vs-wire reconciliation, per operator family.
            match &cspec {
                // Sparse family: accounting is footnote 5's fixed-width
                // form, the wire is γ-delta-coded — both recomputed here
                // independently of the implementations.
                CompressorSpec::TopK { .. }
                | CompressorSpec::RandK { .. }
                | CompressorSpec::RandomP { .. }
                | CompressorSpec::BlockTopK { .. }
                | CompressorSpec::Threshold { .. }
                | CompressorSpec::Adaptive { .. } => {
                    let nnz = match &out {
                        Update::Sparse(s) => s.nnz() as u64,
                        Update::Dense(_) => panic!("{spec}: sparse update expected"),
                    };
                    assert_eq!(
                        accounted,
                        nnz * (32 + index_bits(d)),
                        "{spec} t={t}: accounted != footnote-5 form"
                    );
                    assert_eq!(
                        wire,
                        expected_sparse_payload_bits(&out),
                        "{spec} t={t}: wire != independent γ-sum"
                    );
                }
                // Identity: dense raw — wire is exactly the accounted
                // 32·d plus the frame header.
                CompressorSpec::Identity => {
                    assert_eq!(accounted, 32 * d as u64, "{spec} t={t}");
                    assert_eq!(
                        wire,
                        accounted + gamma_bits(TAG_DENSE_RAW) + gamma_bits(d as u64 + 1),
                        "{spec} t={t}: wire != accounted + header"
                    );
                }
                // Sign: wire is exactly the accounted d + 32 plus the
                // frame header.
                CompressorSpec::Sign => {
                    assert_eq!(accounted, d as u64 + 32, "{spec} t={t}");
                    assert_eq!(
                        wire,
                        accounted + gamma_bits(TAG_SIGN) + gamma_bits(d as u64 + 1),
                        "{spec} t={t}: wire != accounted + header"
                    );
                }
                // QSGD: the accounting is Appendix B's closed-form
                // *estimate* — by design not a per-payload count. The
                // reconciliation is explicit: assert the estimate's
                // formula, and that the measured payload (validated
                // exact above) is what the wire path reports.
                CompressorSpec::Qsgd { levels, eff } => {
                    let deff = eff.unwrap_or(d) as f64;
                    let s = *levels as f64;
                    let naive = (s.log2() + 1.0) * deff;
                    let elias = 3.0 * s * (s + deff.sqrt()) + 32.0;
                    assert_eq!(accounted, naive.min(elias).ceil() as u64, "{spec} t={t}");
                    assert!(wire > 0, "{spec} t={t}");
                }
                // Composed: the accounting is the closed form 32 + nnz·
                // (index + sign + level bits). The wire frame was
                // validated exact above; its norm scalar cannot be
                // recovered from the quantized update alone, so like
                // QSGD the reconciliation asserts the accounted formula
                // and that a positive payload was framed.
                CompressorSpec::Composed { levels, .. } => {
                    let nnz = match &out {
                        Update::Sparse(s) => s.nnz() as u64,
                        Update::Dense(_) => panic!("{spec}: sparse update expected"),
                    };
                    let level_bits = (32 - levels.leading_zeros()) as u64;
                    assert_eq!(
                        accounted,
                        32 + nnz * (index_bits(d) + 1 + level_bits),
                        "{spec} t={t}: accounted != composed closed form"
                    );
                    assert!(wire > 0, "{spec} t={t}");
                }
            }
        }
    }
}

#[test]
fn wire_mode_composes_with_counting_and_local_update() {
    // One combined run: non-default LocalUpdate through a counted
    // channel — the schedule annotations, the simulated equality, and
    // the byte count must all hold at once.
    let data = data();
    let local = LocalUpdate::new(2, 3).unwrap();
    let run_sim = Experiment::new(LogisticModel::new(&data, 1.0 / 240.0))
        .dataset(&data.name)
        .method(MethodSpec::parse("memsgd:threshold:0.25").unwrap())
        .schedule(Schedule::constant(0.4))
        .topology(Topology::ParamServerSync { nodes: 4 })
        .steps(720)
        .eval_points(3)
        .seed(5)
        .local_update(local)
        .run()
        .unwrap();
    let transport = CountingTransport::new(Box::new(Loopback));
    let counter = transport.counter();
    let run_wire = Experiment::new(LogisticModel::new(&data, 1.0 / 240.0))
        .dataset(&data.name)
        .method(MethodSpec::parse("memsgd:threshold:0.25").unwrap())
        .schedule(Schedule::constant(0.4))
        .topology(Topology::ParamServerSync { nodes: 4 })
        .steps(720)
        .eval_points(3)
        .seed(5)
        .local_update(local)
        .wire_transport(Box::new(transport))
        .run()
        .unwrap();
    assert_records_match(&run_sim, &run_wire, "threshold B=2 H=3 counted");
    assert_eq!(run_wire.extra["sync_every"], 3.0);
    assert_eq!(
        run_wire.extra["wire_frame_bits"],
        (counter.load(Ordering::Relaxed) * 8) as f64
    );
}
