//! Cluster-runtime lifecycle suite: the multi-process serve/worker
//! protocol end to end on localhost sockets, and every way a cluster
//! run is allowed to fail.
//!
//! * **Golden multi-process trajectories** — a `ClusterServer` plus
//!   `run_worker` peers (real TCP connections, separate threads standing
//!   in for separate processes — the byte streams are identical) must
//!   reproduce the simulated engines bit for bit on both PS topologies.
//! * **Backend matrix** — every lifecycle and failure case runs under
//!   each server I/O backend ([`IoBackend::Poll`]'s event loop and
//!   [`IoBackend::Threads`]'s reader threads): the backends must be
//!   protocol-indistinguishable, down to the error text.
//! * **Lifecycle** — handshake version/config mismatches are rejected
//!   descriptively on both sides, connect retry gives up after its
//!   bound, a worker dropping mid-round fails the server cleanly, and a
//!   premature/double `SHUTDOWN` fails the worker cleanly — in every
//!   case the run *returns* (no hung barrier) and joins its threads.
//! * **Stress** — a 32-worker run terminates under a watchdog, and the
//!   poll backend serves it without spawning a single per-connection
//!   reader thread.

use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Mutex, MutexGuard};
use std::thread;
use std::time::Duration;

use memsgd::compress::elias::BitWriter;
use memsgd::coordinator::cluster::{
    reader_threads_spawned, run_worker, ClusterServer, IoBackend, RunConfig,
};
use memsgd::coordinator::net::{read_frame, write_frame, Backoff, Hello, PROTOCOL_VERSION};
use memsgd::coordinator::transport::encode_shutdown;
use memsgd::coordinator::{Experiment, FailurePolicy, LocalUpdate, MethodSpec, Topology};
use memsgd::experiments::{self, Which};
use memsgd::metrics::RunRecord;
use memsgd::models::LogisticModel;
use memsgd::optim::Schedule;
use memsgd::sim::network::NetworkModel;
use memsgd::util::json::Json;

/// A deliberately tiny run: epsilon at a scale that floors n at 64
/// samples (d stays the real 2000 — the handshake pins it).
fn test_config(topology: &str, nodes: usize) -> RunConfig {
    RunConfig {
        dataset: "epsilon".into(),
        scale: 100_000,
        seed: 11,
        method: "memsgd:top_k:1".into(),
        schedule: Schedule::constant(0.1),
        steps: 96,
        eval_points: 3,
        nodes,
        local: LocalUpdate::default(),
        topology: topology.into(),
        network: "1g".into(),
        dim: 2000,
        failure_policy: FailurePolicy::FailFast,
        fault_plan: None,
        start_round: 0,
    }
}

/// The backends every case runs under: both where `poll(2)` exists,
/// the threaded fallback alone elsewhere.
fn backends() -> Vec<IoBackend> {
    if cfg!(unix) {
        vec![IoBackend::Poll, IoBackend::Threads]
    } else {
        vec![IoBackend::Threads]
    }
}

/// Tests that assert on the process-global [`reader_threads_spawned`]
/// counter (or bump it by running a `Threads`-backend cluster) hold
/// this guard so parallel test threads cannot skew each other's deltas.
static READER_SERIAL: Mutex<()> = Mutex::new(());

fn reader_serial() -> MutexGuard<'static, ()> {
    READER_SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Snappy retries for tests — the listener already exists when workers
/// dial, so this only matters on the failure paths.
fn fast_backoff() -> Backoff {
    Backoff {
        attempts: 2,
        base: Duration::from_millis(1),
        cap: Duration::from_millis(2),
    }
}

/// Run a full serve + N-worker cluster round trip over localhost TCP
/// under the given I/O backend and hand back the server record plus
/// each worker's (node, bits).
fn cluster_run(cfg: RunConfig, io: IoBackend) -> (RunRecord, Vec<(usize, u64)>) {
    let nodes = cfg.nodes;
    let server = ClusterServer::bind_with_io("127.0.0.1:0", cfg, io).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let server_handle = thread::spawn(move || server.run());
    let workers: Vec<_> = (0..nodes)
        .map(|_| {
            let addr = addr.clone();
            thread::spawn(move || run_worker(&addr, &Hello::any(), &fast_backoff(), false, None))
        })
        .collect();
    let record = server_handle.join().unwrap().unwrap();
    let stats: Vec<(usize, u64)> =
        workers.into_iter().map(|w| w.join().unwrap().unwrap()).collect();
    (record, stats)
}

/// The simulated twin of [`test_config`] through the Experiment builder.
fn simulated_twin(cfg: &RunConfig, topology: Topology) -> RunRecord {
    let which = Which::parse(&cfg.dataset).unwrap();
    let data = experiments::dataset(which, cfg.scale, cfg.seed);
    Experiment::new(LogisticModel::new(&data, 1.0 / data.n() as f64))
        .dataset(&data.name)
        .method(MethodSpec::parse(&cfg.method).unwrap())
        .schedule(cfg.schedule.clone())
        .topology(topology)
        .steps(cfg.steps)
        .eval_points(cfg.eval_points)
        .seed(cfg.seed)
        .local_update(cfg.local)
        .run()
        .unwrap()
}

/// Bit-for-bit equality of everything the simulation reports, with the
/// cluster record allowed to add `wire_*`/`cluster` extras on top.
fn assert_cluster_matches_sim(sim: &RunRecord, cluster: &RunRecord, label: &str) {
    assert_eq!(sim.method, cluster.method, "{label}: method");
    assert_eq!(sim.dataset, cluster.dataset, "{label}: dataset");
    assert_eq!(sim.schedule, cluster.schedule, "{label}: schedule");
    assert_eq!(sim.steps, cluster.steps, "{label}: steps");
    assert_eq!(sim.total_bits, cluster.total_bits, "{label}: total_bits");
    assert_eq!(sim.curve, cluster.curve, "{label}: loss curve (bit-for-bit)");
    for (key, val) in &sim.extra {
        assert_eq!(cluster.extra.get(key), Some(val), "{label}: extra[{key}] diverged");
    }
    assert_eq!(cluster.extra.get("cluster"), Some(&1.0), "{label}: cluster marker");
}

#[test]
fn multiprocess_sync_run_reproduces_the_simulated_trajectory() {
    let _serial = reader_serial();
    let cfg = test_config("ps-sync", 2);
    let sim = simulated_twin(&cfg, Topology::ParamServerSync { nodes: 2 });
    for io in backends() {
        let label = format!("ps-sync cluster [{}]", io.name());
        let (record, stats) = cluster_run(cfg.clone(), io);
        assert_cluster_matches_sim(&sim, &record, &label);

        // Node ids are assigned in accept order: exactly 0..nodes, each
        // worker reporting the accounted upload bits the server tallied.
        let mut nodes: Vec<usize> = stats.iter().map(|&(n, _)| n).collect();
        nodes.sort_unstable();
        assert_eq!(nodes, vec![0, 1], "{label}");
        let uploaded: u64 = stats.iter().map(|&(_, b)| b).sum();
        assert!(uploaded > 0, "{label}: workers uploaded nothing");
        assert!(
            uploaded <= record.total_bits,
            "{label}: worker bits exceed the accounted total"
        );
    }
}

#[test]
fn multiprocess_async_run_reproduces_the_simulated_trajectory() {
    let _serial = reader_serial();
    let cfg = test_config("ps-async", 2);
    let sim = simulated_twin(
        &cfg,
        Topology::ParamServerAsync { nodes: 2, net: NetworkModel::eth_1g() },
    );
    for io in backends() {
        let label = format!("ps-async cluster [{}]", io.name());
        let (record, stats) = cluster_run(cfg.clone(), io);
        assert_cluster_matches_sim(&sim, &record, &label);
        for key in ["mean_staleness", "max_staleness", "sim_seconds", "link_utilization"] {
            assert_eq!(sim.extra[key], record.extra[key], "{label}: {key}");
        }
        assert_eq!(stats.len(), 2, "{label}");
    }
}

#[test]
fn handshake_version_mismatch_is_rejected_descriptively() {
    for io in backends() {
        let label = io.name();
        let server =
            ClusterServer::bind_with_io("127.0.0.1:0", test_config("ps-sync", 1), io).unwrap();
        let addr = server.local_addr().unwrap();
        let server_handle = thread::spawn(move || server.run());

        let mut stream = TcpStream::connect(addr).unwrap();
        let from_the_future = Hello { proto: 99, ..Hello::any() };
        write_frame(&mut stream, &from_the_future.encode()).unwrap();
        let reply = read_frame(&mut stream, 1 << 20).unwrap();
        let j = Json::parse(std::str::from_utf8(&reply).unwrap()).unwrap();
        let reason = j.req("error").unwrap().as_str().unwrap().to_string();
        assert!(
            reason.contains("protocol version mismatch"),
            "[{label}] reject reason not descriptive: {reason}"
        );
        assert!(
            reason.contains("99"),
            "[{label}] reject reason omits the offered version: {reason}"
        );

        // The server fails the whole run (and returns — no hung accept
        // loop, every thread joined inside run()).
        let err = server_handle.join().unwrap().unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("handshake"),
            "[{label}] server error not about the handshake: {msg}"
        );
        assert!(
            msg.contains("protocol version mismatch"),
            "[{label}] server error lost the cause: {msg}"
        );
    }
}

#[test]
fn worker_expectation_mismatch_fails_both_sides() {
    for io in backends() {
        let label = io.name();
        let server =
            ClusterServer::bind_with_io("127.0.0.1:0", test_config("ps-sync", 1), io).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_handle = thread::spawn(move || server.run());

        // This worker insists the cluster run plain SGD; the server is
        // running memsgd:top_k:1 — a half-compatible cluster would
        // silently diverge, so both ends must refuse.
        let expect = Hello { method: "sgd".into(), ..Hello::any() };
        let worker_err = run_worker(&addr, &expect, &fast_backoff(), false, None).unwrap_err();
        let worker_msg = format!("{worker_err:#}");
        assert!(
            worker_msg.contains("server rejected handshake"),
            "[{label}] worker error misses the rejection: {worker_msg}"
        );
        assert!(
            worker_msg.contains("method mismatch"),
            "[{label}] worker error misses the cause: {worker_msg}"
        );

        let server_err = server_handle.join().unwrap().unwrap_err();
        let server_msg = format!("{server_err:#}");
        assert!(
            server_msg.contains("method mismatch"),
            "[{label}] server error misses the cause: {server_msg}"
        );
    }
}

#[test]
fn connect_retry_gives_up_after_the_bound() {
    // Bind a port, then free it: connecting must fail fast (refused),
    // and run_worker must give up after exactly the configured bound.
    let addr = {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.local_addr().unwrap().to_string()
    };
    let err = run_worker(&addr, &Hello::any(), &fast_backoff(), false, None).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("after 2 attempts"),
        "retry bound not reported: {msg}"
    );
}

#[test]
fn worker_dropping_mid_round_fails_the_server_cleanly() {
    let _serial = reader_serial();
    for io in backends() {
        let label = io.name();
        let server =
            ClusterServer::bind_with_io("127.0.0.1:0", test_config("ps-sync", 1), io).unwrap();
        let addr = server.local_addr().unwrap();
        let server_handle = thread::spawn(move || server.run());

        // Handshake correctly, then vanish before round 0's UPLOAD.
        let mut stream = TcpStream::connect(addr).unwrap();
        write_frame(&mut stream, &Hello::any().encode()).unwrap();
        let welcome = read_frame(&mut stream, 1 << 20).unwrap();
        let j = Json::parse(std::str::from_utf8(&welcome).unwrap()).unwrap();
        assert!(j.get("error").is_none(), "[{label}] handshake unexpectedly rejected");
        drop(stream);

        // The server must notice the EOF and fail the run — not sit on
        // the barrier for a worker that will never upload.
        let err = server_handle.join().unwrap().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("node 0"), "[{label}] server error names no node: {msg}");
        assert!(
            msg.contains("connection lost") || msg.contains("connection closed"),
            "[{label}] server error misses the disconnect: {msg}"
        );
    }
}

#[test]
fn premature_double_shutdown_fails_the_worker_cleanly() {
    // A hostile "server" that handshakes correctly, then fires SHUTDOWN
    // twice instead of running round 0. The sync worker is owed a
    // BROADCAST — it must bail descriptively, not hang or panic.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cfg = test_config("ps-sync", 1);
    let fake_server = thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        let hello = read_frame(&mut stream, 1 << 20).unwrap();
        Hello::decode(&hello).unwrap();
        let welcome = Json::obj(vec![
            ("proto", Json::str(PROTOCOL_VERSION.to_string())),
            ("node", Json::Num(0.0)),
            ("config", cfg.to_json()),
        ])
        .to_string();
        write_frame(&mut stream, welcome.as_bytes()).unwrap();
        let mut w = BitWriter::new();
        encode_shutdown(&mut w);
        let frame = w.as_bytes().to_vec();
        write_frame(&mut stream, &frame).unwrap();
        write_frame(&mut stream, &frame).unwrap();
        stream // keep the socket open until the worker has decided
    });

    let err = run_worker(&addr, &Hello::any(), &fast_backoff(), false, None).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unexpected"), "worker error misses the bogus message: {msg}");
    drop(fake_server.join().unwrap());
}

/// 32 workers against one server: the run must terminate under a
/// watchdog on every backend, the poll backend must serve the whole
/// data plane without spawning a single per-connection reader thread,
/// and the threads backend must spawn exactly one per node.
#[test]
fn stress_32_workers_terminate_without_leaking_reader_threads() {
    let _serial = reader_serial();
    let nodes = 32;
    for io in backends() {
        let before = reader_threads_spawned();
        let cfg = test_config("ps-sync", nodes);
        let (tx, rx) = mpsc::channel();
        let handle = thread::spawn(move || {
            tx.send(cluster_run(cfg, io)).ok();
        });
        let (record, stats) = match rx.recv_timeout(Duration::from_secs(120)) {
            Ok(result) => result,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                panic!("32-worker cluster hung past the watchdog [io={}]", io.name())
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // The runner thread panicked before sending — propagate.
                handle.join().unwrap();
                unreachable!("runner thread exited without a result");
            }
        };
        handle.join().unwrap();

        let label = format!("32-worker stress [{}]", io.name());
        assert_eq!(record.steps, 96, "{label}: steps");
        let mut ids: Vec<usize> = stats.iter().map(|&(n, _)| n).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..nodes).collect::<Vec<_>>(), "{label}: node ids");
        let uploaded: u64 = stats.iter().map(|&(_, b)| b).sum();
        assert!(uploaded > 0, "{label}: workers uploaded nothing");

        let spawned = reader_threads_spawned() - before;
        match io {
            IoBackend::Poll => assert_eq!(
                spawned, 0,
                "{label}: the poll backend must not spawn reader threads"
            ),
            IoBackend::Threads => assert_eq!(
                spawned, nodes,
                "{label}: one reader thread per node expected"
            ),
        }
    }
}
