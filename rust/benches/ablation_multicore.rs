//! Robustness ablation for the Figure-4 DES substitution (DESIGN.md §3):
//! the paper's qualitative conclusion — *sparse updates scale near-
//! linearly, dense lock-free writers saturate early* — must hold across
//! the simulator's whole plausible parameter range, not just at the
//! calibrated defaults. If the conclusion flipped under a reasonable
//! write cost or miss penalty, the substitution would be unsound.
//!
//! Run: `cargo bench --bench ablation_multicore`

use memsgd::sim::{speedup_series, SimConfig, WritePattern};
use memsgd::util::bench::Bench;
use std::time::Instant;

fn speedup_at(cfg: &SimConfig, w: usize) -> f64 {
    speedup_series(cfg, &[w]).pop().unwrap().speedup
}

fn main() {
    let mut b = Bench::slow("ablation_multicore");
    let workers = 12usize;

    // Parameter grid around the calibrated defaults (×1/4 .. ×4).
    let write_costs = [1.25f64, 5.0, 20.0];
    let miss_penalties = [0.75f64, 3.0, 12.0];
    let bus_fixed = [37.5f64, 150.0, 600.0];

    let started = Instant::now();
    let mut cells = 0usize;
    let mut min_gap = f64::INFINITY;
    println!("\n  sparse-vs-dense speedup at W={workers} across the parameter grid:");
    println!(
        "  {:>8} {:>8} {:>8} | {:>8} {:>8} {:>6}",
        "write", "miss", "bus", "top-1", "dense", "ratio"
    );
    for &write_ns in &write_costs {
        for &miss_penalty_ns in &miss_penalties {
            for &bus in &bus_fixed {
                let base = SimConfig {
                    write_ns,
                    miss_penalty_ns,
                    bus_fixed_ns: bus,
                    total_updates: 12_000,
                    ..SimConfig::default()
                };
                let sparse = speedup_at(
                    &SimConfig {
                        pattern: WritePattern::Uniform { k: 1 },
                        ..base.clone()
                    },
                    workers,
                );
                let dense = speedup_at(
                    &SimConfig {
                        pattern: WritePattern::Dense,
                        ..base.clone()
                    },
                    workers,
                );
                let ratio = sparse / dense;
                min_gap = min_gap.min(ratio);
                cells += 1;
                println!(
                    "  {write_ns:>8.2} {miss_penalty_ns:>8.2} {bus:>8.1} | {sparse:>8.2} {dense:>8.2} {ratio:>6.2}"
                );
                // The paper's ordering must hold in EVERY cell.
                assert!(
                    sparse > dense,
                    "ordering flipped at write={write_ns} miss={miss_penalty_ns} bus={bus}"
                );
                // And sparse must retain meaningful parallelism.
                assert!(
                    sparse > 3.0,
                    "sparse scaling collapsed at write={write_ns} miss={miss_penalty_ns} bus={bus}: {sparse:.2}"
                );
            }
        }
    }
    b.record(
        &format!("grid {cells} cells x 2 patterns x W={workers}"),
        started.elapsed(),
        cells * 2 * 12_000,
    );
    println!("\n  minimum sparse/dense speedup ratio over the grid: {min_gap:.2} (> 1 required)");

    // Secondary claim: top-k's deterministic coordinate preference loses
    // more updates to collisions than rand-k (paper §4.4), at defaults.
    let started = Instant::now();
    let base = SimConfig {
        total_updates: 20_000,
        ..SimConfig::default()
    };
    let popular = speedup_series(
        &SimConfig {
            pattern: WritePattern::Popular { k: 1, hot_fraction: 0.01 },
            ..base.clone()
        },
        &[24],
    )
    .pop()
    .unwrap();
    let uniform = speedup_series(
        &SimConfig {
            pattern: WritePattern::Uniform { k: 1 },
            ..base
        },
        &[24],
    )
    .pop()
    .unwrap();
    b.record("collision contrast (2 runs, W=24)", started.elapsed(), 40_000);
    println!(
        "  lost updates at W=24: popular(top-k-like) {} vs uniform(rand-k) {}",
        popular.lost_updates, uniform.lost_updates
    );
    assert!(
        popular.lost_updates > uniform.lost_updates,
        "top-k-like pattern should collide more"
    );

    b.finish();
}
