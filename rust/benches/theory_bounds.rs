//! Theory-validation bench: Lemma 3.2's memory envelope and Theorem
//! 2.4's suboptimality bound evaluated against live runs — the
//! "executable mathematics" check that the implementation and the
//! analysis describe the same algorithm.
//!
//! Run: `cargo bench --bench theory_bounds`

use memsgd::experiments::extensions;
use memsgd::experiments::Which;
use memsgd::optim::theory::{lemma_a3_max_ratio, TheoryParams};
use memsgd::util::bench::Bench;
use std::time::Instant;

fn main() {
    let mut b = Bench::slow("theory_bounds");

    // --- Lemma 3.2 on live runs: measured ‖m_t‖² under the envelope.
    for spec in ["top_k:1", "rand_k:1", "top_k:10"] {
        let started = Instant::now();
        let tr = extensions::memory_trace(Which::Epsilon, 400, 12_000, spec, 1)
            .expect("memory trace failed");
        b.record(&format!("memory_trace {spec}"), started.elapsed(), 12_000);
        println!(
            "  {spec:<10} max ‖m_t‖²/bound = {:.3e} (≤ 1 required; a = {:.0})",
            tr.max_ratio, tr.shift
        );
        assert!(tr.max_ratio <= 1.0, "{spec}: Lemma 3.2 violated");
        assert!(tr.max_ratio > 0.0, "{spec}: degenerate trace");
    }

    // --- Lemma A.3 recursion, numerically, across the (d, k, α) grid.
    let started = Instant::now();
    let mut worst: f64 = 0.0;
    for &(d, k) in &[(2_000usize, 1.0f64), (2_000, 10.0), (47_236, 10.0), (64, 64.0)] {
        for &alpha in &[4.5f64, 5.0, 8.0] {
            let rho = 4.0 * alpha / ((alpha - 4.0) * (alpha + 1.0).powi(2));
            let a = (((alpha + 1.0) * d as f64 / k + rho + 1.0) / (rho + 1.0)).ceil();
            let r = lemma_a3_max_ratio(d, k, alpha, a, 50_000);
            worst = worst.max(r);
            assert!(
                r <= 1.0 + 1e-9,
                "Lemma A.3 violated at d={d} k={k} alpha={alpha}: {r}"
            );
        }
    }
    b.record("lemma_a3 grid (12 cells x 50k)", started.elapsed(), 600_000);
    println!("  lemma A.3 worst h_t/bound over grid: {worst:.4}");

    // --- Theorem 2.4: the transient horizon scales as (d/k)·√κ.
    let started = Instant::now();
    let horizon = |d: usize, k: f64, kappa: f64| {
        TheoryParams {
            d,
            k,
            g_sq: 1.0,
            mu: 1.0,
            ell: kappa,
            x0_dist_sq: 1.0,
            alpha: 5.0,
        }
        .transient_horizon()
    };
    let h1 = horizon(2_000, 1.0, 100.0);
    let h2 = horizon(2_000, 10.0, 100.0);
    let h3 = horizon(2_000, 1.0, 400.0);
    b.record("transient_horizon probes", started.elapsed(), 3);
    println!("  T*(k=1) = {h1:.0}, T*(k=10) = {h2:.0}, T*(4x kappa) = {h3:.0}");
    // The FULL eq.-(9) crossover (term1 > term2 + term3 at the Remark-2.5
    // shift) balances G²/(μT) against (d/k)²κG²/(μT²), so it scales as
    // (d/k)²·κ — quadratic in d/k, linear in κ. (Remark 2.6's Ω((d/k)√κ)
    // quote describes when the THIRD term alone is dominated; the second
    // term's crossover is the binding one and is what we probe.)
    // Power-of-two probing adds up to 2× slack on each side.
    let kfac = h1 / h2; // (d/k) ratio 10 → expect ~100× within [32, 256]
    assert!(
        (32.0..=256.0).contains(&kfac),
        "(d/k)^2 scaling off: {kfac:.1}"
    );
    let kapfac = h3 / h1; // κ ratio 4 → expect ~4× within [2, 8]
    assert!(
        (2.0..=8.0).contains(&kapfac),
        "linear-kappa scaling off: {kapfac:.1}"
    );

    b.finish();
}
