//! Figure 4 regeneration bench: the threaded Algorithm 2 (convergence
//! under concurrency at fixed budget) and the discrete-event 24-core
//! speedup model, with the paper's qualitative assertions.
//!
//! Run: `cargo bench --bench figure4_multicore`

use memsgd::experiments::{self, Which};
use memsgd::util::bench::Bench;
use std::time::Instant;

fn main() {
    let mut b = Bench::slow("figure4_multicore");
    let workers = [1usize, 2, 4, 8, 12, 16, 20, 24];

    for which in [Which::Epsilon, Which::Rcv1] {
        // --- DES speedup series -----------------------------------------
        let started = Instant::now();
        let series = experiments::figure4_sim(which, &workers, 1);
        b.record(
            &format!("figure4 sim {} (3 series x 8 points)", which.name()),
            started.elapsed(),
            24,
        );
        println!("{}", experiments::sim_table(&series));

        let at = |name: &str, w: usize| {
            series
                .iter()
                .find(|s| s.method.contains(name))
                .and_then(|s| s.points.iter().find(|p| p.workers == w))
                .map(|p| p.speedup)
                .unwrap()
        };
        // Paper claims: near-linear to ~10 cores for sparse updates...
        assert!(at("top_", 8) > 6.0, "sparse speedup at 8: {}", at("top_", 8));
        // ...dense lock-free saturates far earlier...
        assert!(at("dense", 24) < 6.0, "dense at 24: {}", at("dense", 24));
        // ...and sparse clearly dominates dense at high counts.
        assert!(at("top_", 16) > 2.0 * at("dense", 16));
    }

    // --- threaded Algorithm 2 at fixed budget ----------------------------
    let started = Instant::now();
    let recs = experiments::figure4_threads(Which::Epsilon, 200, 20_000, &[1, 2, 4], 1)
        .expect("threads failed");
    b.record("figure4 threads epsilon (3 comps x 3 W)", started.elapsed(), 9);
    for r in &recs {
        println!(
            "  W={} {:<34} final loss {:.4}",
            r.extra["workers"], r.method, r.final_loss()
        );
    }
    // Sparse Mem-SGD must stay convergent as workers increase.
    let top_w1 = recs
        .iter()
        .find(|r| r.method.contains("top_k") && r.extra["workers"] == 1.0)
        .unwrap();
    let top_w4 = recs
        .iter()
        .find(|r| r.method.contains("top_k") && r.extra["workers"] == 4.0)
        .unwrap();
    assert!(
        (top_w4.final_loss() - top_w1.final_loss()).abs() < 0.1,
        "top-k W=4 degraded: {} vs {}",
        top_w4.final_loss(),
        top_w1.final_loss()
    );
    b.finish();
}
