//! Hot-path benchmark: the full Mem-SGD iteration (gradient + compress +
//! memory update) against the vanilla-SGD iteration, per dataset shape.
//!
//! DESIGN.md §7 target: Mem-SGD top-1's iteration must cost ≤ 2× a
//! vanilla dense-SGD iteration at d = 2000 — compression must not eat
//! the communication win. This bench regenerates that number, plus the
//! breakdown (gradient / compress / memory) used in EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --bench hot_path`

use memsgd::compress::{self, Update};
use memsgd::data::synthetic;
use memsgd::models::{GradBackend, LogisticModel};
use memsgd::optim::{MemSgd, Sgd};
use memsgd::util::bench::Bench;
use memsgd::util::prng::Prng;

fn main() {
    let mut b = Bench::new("hot_path");

    // --- dense epsilon shape ------------------------------------------------
    {
        let data = synthetic::epsilon_like(2_000, 2_000, 1);
        let mut model = LogisticModel::with_paper_lambda(&data);
        let d = data.d();
        let mut grad = vec![0.0f32; d];
        let x = vec![0.01f32; d];
        let mut i = 0usize;

        b.run("grad only           dense d=2000", || {
            model.sample_grad(&x, i % 2_000, &mut grad);
            i += 1;
        });

        let mut rng = Prng::new(3);
        let mut mem = MemSgd::new(vec![0.0; d], compress::from_spec("top_k:1").unwrap());
        b.run("memsgd top_1 step   dense d=2000", || {
            model.sample_grad(&mem.x, i % 2_000, &mut grad);
            mem.step(&grad, 1e-3, &mut rng);
            i += 1;
        });

        let mut sgd = Sgd::vanilla(vec![0.0; d]);
        b.run("vanilla sgd step    dense d=2000", || {
            model.sample_grad(&sgd.x, i % 2_000, &mut grad);
            sgd.step(&grad, 1e-3, &mut rng);
            i += 1;
        });

        // isolated compress+memory cost
        let mut comp = compress::from_spec("top_k:1").unwrap();
        let mut out = Update::new_sparse(d);
        b.run("compress+mem only   dense d=2000", || {
            comp.compress(&grad, &mut rng, &mut out);
        });
    }

    // --- sparse rcv1 shape ----------------------------------------------------
    {
        let data = synthetic::rcv1_like(2_000, 47_236, 0.0015, 2);
        let mut model = LogisticModel::with_paper_lambda(&data);
        let d = data.d();
        let mut grad = vec![0.0f32; d];
        let mut rng = Prng::new(4);
        let mut i = 0usize;

        let mut mem = MemSgd::new(vec![0.0; d], compress::from_spec("top_k:10").unwrap());
        b.run("memsgd top_10 step  sparse d=47236", || {
            model.sample_grad(&mem.x, i % 2_000, &mut grad);
            mem.step(&grad, 1e-3, &mut rng);
            i += 1;
        });

        let mut sgd = Sgd::vanilla(vec![0.0; d]);
        b.run("vanilla sgd step    sparse d=47236", || {
            model.sample_grad(&sgd.x, i % 2_000, &mut grad);
            sgd.step(&grad, 1e-3, &mut rng);
            i += 1;
        });
    }

    // --- batched gradients (local-update schedule hot path) -------------------
    // One minibatch gradient per call; the per-sample cost should stay
    // ~flat in B (single accumulation pass, no scratch allocation).
    {
        let data = synthetic::epsilon_like(2_000, 2_000, 5);
        let mut model = LogisticModel::with_paper_lambda(&data);
        let d = data.d();
        let mut grad = vec![0.0f32; d];
        let x = vec![0.01f32; d];
        let mut t = 0usize;
        for bsz in [1usize, 8, 64] {
            let mut idx = vec![0usize; bsz];
            b.run(&format!("grad batch B={bsz:<2}     dense d=2000"), || {
                for slot in idx.iter_mut() {
                    *slot = t % 2_000;
                    t += 1;
                }
                model.sample_grad_batch(&x, &idx, &mut grad);
            });
        }
    }
    {
        let data = synthetic::rcv1_like(2_000, 47_236, 0.0015, 6);
        let mut model = LogisticModel::with_paper_lambda(&data);
        let d = data.d();
        let mut grad = vec![0.0f32; d];
        let x = vec![0.01f32; d];
        let mut t = 0usize;
        for bsz in [1usize, 8, 64] {
            let mut idx = vec![0usize; bsz];
            b.run(&format!("grad batch B={bsz:<2}     sparse d=47236"), || {
                for slot in idx.iter_mut() {
                    *slot = t % 2_000;
                    t += 1;
                }
                model.sample_grad_batch(&x, &idx, &mut grad);
            });
        }
    }

    // --- weighted averaging overhead ------------------------------------------
    {
        let d = 2_000;
        let mut avg = memsgd::optim::WeightedAverage::new(d, 2_000.0);
        let x = vec![0.5f32; d];
        b.run("weighted avg update dense d=2000", || {
            avg.update(&x);
        });
    }

    b.finish();
    // Accumulate the perf trajectory: every run appends its rows. Skip
    // when the MEMSGD_BENCH_JSON hook is active — finish() already wrote
    // there, and appending twice would duplicate the rows.
    if std::env::var_os("MEMSGD_BENCH_JSON").is_none() {
        match b.write_json("BENCH_hot_path.json") {
            Ok(()) => println!("perf rows appended -> BENCH_hot_path.json"),
            Err(e) => eprintln!("could not write BENCH_hot_path.json: {e}"),
        }
    }

    // The §7 acceptance check, printed for EXPERIMENTS.md:
    let ratio_cases: Vec<(&str, f64)> = b
        .results
        .iter()
        .filter(|m| m.name.contains("d=2000") && m.name.contains("step"))
        .map(|m| (m.name.trim(), m.mean_ns))
        .collect();
    if let (Some(mem), Some(sgd)) = (
        ratio_cases.iter().find(|c| c.0.contains("memsgd")),
        ratio_cases.iter().find(|c| c.0.contains("vanilla")),
    ) {
        println!(
            "\nDESIGN §7 check: memsgd/vanilla iteration ratio = {:.2} (target <= 2.0)",
            mem.1 / sgd.1
        );
    }
}
