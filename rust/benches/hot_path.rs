//! Hot-path benchmark: the full Mem-SGD iteration (gradient + compress +
//! memory update) against the vanilla-SGD iteration, per dataset shape —
//! plus the sparse gradient pipeline's local-step payoff at the RCV1
//! shape (d = 47 236, nnz ≈ 100, B ∈ {1, 8, 64}).
//!
//! DESIGN.md §7 target: Mem-SGD top-1's iteration must cost ≤ 2× a
//! vanilla dense-SGD iteration at d = 2000 — compression must not eat
//! the communication win. This bench regenerates that number, plus the
//! breakdown (gradient / compress / memory) used in EXPERIMENTS.md §Perf.
//!
//! Every run merges its rows into `BENCH_hot_path.json` (schema in
//! `util::bench`), the committed CI performance baseline: the
//! `bench-gate` CI job reruns this bench and fails on a >25% normalized
//! median regression for any case, or if the sparse local step loses
//! its ≥5× edge over the dense one, or if the active-set phase sync
//! loses its ≥5× edge over the dense `O(d)` sync (`util::gate`).
//!
//! Run: `cargo bench --bench hot_path`

use memsgd::compress::{self, ActiveView, SparseVec, Update};
use memsgd::data::synthetic;
use memsgd::models::{GradBackend, LogisticModel};
use memsgd::optim::{ErrorFeedbackStep, MemSgd, Sgd};
use memsgd::util::bench::Bench;
use memsgd::util::gate;
use memsgd::util::prng::Prng;

fn main() {
    // Bench title and the gate-relevant case names come from
    // `util::gate` so the CI policy cannot silently desynchronize from
    // what this bench measures.
    let mut b = Bench::new(gate::HOT_PATH_BENCH);

    // --- dense epsilon shape ------------------------------------------------
    {
        let data = synthetic::epsilon_like(2_000, 2_000, 1);
        let mut model = LogisticModel::with_paper_lambda(&data);
        let d = data.d();
        let mut grad = vec![0.0f32; d];
        let x = vec![0.01f32; d];
        let mut i = 0usize;

        b.run(gate::CAL_CASE, || {
            model.sample_grad(&x, i % 2_000, &mut grad);
            i += 1;
        });

        let mut rng = Prng::new(3);
        let mut mem = MemSgd::new(vec![0.0; d], compress::from_spec("top_k:1").unwrap());
        b.run("memsgd top_1 step   dense d=2000", || {
            model.sample_grad(&mem.x, i % 2_000, &mut grad);
            mem.step(&grad, 1e-3, &mut rng);
            i += 1;
        });

        let mut sgd = Sgd::vanilla(vec![0.0; d]);
        b.run("vanilla sgd step    dense d=2000", || {
            model.sample_grad(&sgd.x, i % 2_000, &mut grad);
            sgd.step(&grad, 1e-3, &mut rng);
            i += 1;
        });

        // isolated compress+memory cost
        let mut comp = compress::from_spec("top_k:1").unwrap();
        let mut out = Update::new_sparse(d);
        b.run("compress+mem only   dense d=2000", || {
            comp.compress(&grad, &mut rng, &mut out);
        });
    }

    // --- sparse rcv1 shape ----------------------------------------------------
    {
        let data = synthetic::rcv1_like(2_000, 47_236, 0.0015, 2);
        let mut model = LogisticModel::with_paper_lambda(&data);
        let d = data.d();
        let mut grad = vec![0.0f32; d];
        let mut rng = Prng::new(4);
        let mut i = 0usize;

        let mut mem = MemSgd::new(vec![0.0; d], compress::from_spec("top_k:10").unwrap());
        b.run("memsgd top_10 step  sparse d=47236", || {
            model.sample_grad(&mem.x, i % 2_000, &mut grad);
            mem.step(&grad, 1e-3, &mut rng);
            i += 1;
        });

        let mut sgd = Sgd::vanilla(vec![0.0; d]);
        b.run("vanilla sgd step    sparse d=47236", || {
            model.sample_grad(&sgd.x, i % 2_000, &mut grad);
            sgd.step(&grad, 1e-3, &mut rng);
            i += 1;
        });
    }

    // --- batched gradients (local-update schedule hot path) -------------------
    // One minibatch gradient per call; the per-sample cost should stay
    // ~flat in B (single accumulation pass, no scratch allocation).
    // B = 1 is skipped here: `sample_grad_batch` with one index is the
    // exact `sample_grad` call already measured as the calibration case
    // ("grad only dense d=2000") — a second baseline row for the same
    // measurement just doubled the gate surface.
    {
        let data = synthetic::epsilon_like(2_000, 2_000, 5);
        let mut model = LogisticModel::with_paper_lambda(&data);
        let d = data.d();
        let mut grad = vec![0.0f32; d];
        let x = vec![0.01f32; d];
        let mut t = 0usize;
        for bsz in [8usize, 64] {
            let mut idx = vec![0usize; bsz];
            b.run(&format!("grad batch B={bsz:<2}     dense d=2000"), || {
                for slot in idx.iter_mut() {
                    *slot = t % 2_000;
                    t += 1;
                }
                model.sample_grad_batch(&x, &idx, &mut grad);
            });
        }
    }
    {
        let data = synthetic::rcv1_like(2_000, 47_236, 0.0015, 6);
        let mut model = LogisticModel::with_paper_lambda(&data);
        let d = data.d();
        let mut grad = vec![0.0f32; d];
        let x = vec![0.01f32; d];
        let mut t = 0usize;
        for bsz in [1usize, 8, 64] {
            let mut idx = vec![0usize; bsz];
            b.run(&format!("grad batch B={bsz:<2}     sparse d=47236"), || {
                for slot in idx.iter_mut() {
                    *slot = t % 2_000;
                    t += 1;
                }
                model.sample_grad_batch(&x, &idx, &mut grad);
            });
        }
    }

    // --- sparse local-update pipeline (RCV1 shape, the paper's workload) -----
    // One local step = minibatch gradient + fused accumulator/iterate
    // update. The dense path pays two O(d) passes per step; the sparse
    // path (λ = 0 CSR: gradients are scaled rows) pays O(B·nnz). At
    // d/nnz ≈ 470 the B=1 pair is the tentpole's ≥5× gate invariant.
    {
        let data = synthetic::rcv1_like(2_000, 47_236, 0.00212, 8); // ~100 nnz/row
        let mut model = LogisticModel::new(&data, 0.0);
        assert!(model.supports_sparse_grad());
        let d = data.d();
        let mut grad = vec![0.0f32; d];
        let mut sgrad = SparseVec::new(d);
        let mut acc = vec![0.0f32; d];
        let mut x_loc = vec![0.01f32; d];
        let eta = 1e-3f32;
        let mut t = 0usize;
        for bsz in [1usize, 8, 64] {
            let mut idx = vec![0usize; bsz];
            b.run(&gate::local_step_dense_case(bsz), || {
                for slot in idx.iter_mut() {
                    *slot = t % 2_000;
                    t += 1;
                }
                model.sample_grad_batch(&x_loc, &idx, &mut grad);
                for ((a, xl), &g) in acc.iter_mut().zip(x_loc.iter_mut()).zip(&grad) {
                    let step = eta * g;
                    *a += step;
                    *xl -= step;
                }
            });
        }
        acc.iter_mut().for_each(|a| *a = 0.0);
        x_loc.iter_mut().for_each(|x| *x = 0.01);
        for bsz in [1usize, 8, 64] {
            let mut idx = vec![0usize; bsz];
            b.run(&gate::local_step_sparse_case(bsz), || {
                for slot in idx.iter_mut() {
                    *slot = t % 2_000;
                    t += 1;
                }
                model.sample_grad_batch_sparse(&x_loc, &idx, &mut sgrad);
                sgrad.local_step(eta, &mut acc, &mut x_loc);
            });
        }
    }

    // --- phase-sync cost: dense O(d) route vs active-set O(touched) ----------
    // The communication event the schedule amortizes H-fold. The dense
    // route pays the full-dimension `v = m + accum` pass plus the
    // compressor scan every sync; the active route visits only
    // `support(m) ∪ touched(accum)` — its cost must track the active-set
    // size `a`, not d (the a ∈ {100, 1000, 10000} cases pin the scaling,
    // and the a=100-vs-dense pair is the gate's second ≥5× invariant).
    {
        let d = 47_236usize;
        let mk_acc = |a: usize| -> (Vec<f32>, Vec<u32>) {
            let mut vals = vec![0.0f32; d];
            let mut touched = Vec::with_capacity(a);
            let stride = d / a;
            for i in 0..a {
                let j = i * stride;
                vals[j] = ((i % 13) as f32 - 6.0) * 0.01 + 0.001; // never exactly zero
                touched.push(j as u32);
            }
            (vals, touched)
        };
        let mut rng = Prng::new(9);
        let (acc_dense, _) = mk_acc(100);
        // `sync` takes the dense entry point, so this state stays on the
        // historical O(d) route even though top-k could scan actively.
        let mut ef = ErrorFeedbackStep::new(d, compress::from_spec("top_k:10").unwrap());
        b.run(&gate::phase_sync_dense_case(), || {
            ef.sync(&acc_dense, &mut rng);
        });
        for a in [100usize, 1_000, 10_000] {
            let (vals, touched) = mk_acc(a);
            let mut ef = ErrorFeedbackStep::new(d, compress::from_spec("top_k:10").unwrap());
            b.run(&gate::phase_sync_active_case(a), || {
                ef.sync_active(ActiveView { vals: &vals, touched: &touched }, &mut rng);
            });
        }
    }

    // --- wire codec throughput (the threaded engines' per-message cost) ------
    // Framed encode + decode of the two payload families the wire
    // engines ship every sync: a top-10 sparse update at the RCV1
    // dimension and a QSGD level stream at the epsilon dimension.
    // Regression-gated via the committed baseline rows.
    {
        use memsgd::compress::elias::{decode_payload, BitReader, BitWriter};
        use memsgd::compress::Compressor;

        let d = 47_236usize;
        let mut comp = compress::from_spec("top_k:10").unwrap();
        let mut rng = Prng::new(11);
        let mut out = Update::new_sparse(d);
        let x: Vec<f32> = (0..d).map(|i| ((i % 97) as f32 - 48.0) * 0.01).collect();
        comp.compress(&x, &mut rng, &mut out);
        let mut w = BitWriter::new();
        b.run(&gate::wire_encode_sparse_case(), || {
            w.clear();
            comp.encode_payload(&out, &mut w);
        });
        let bytes = w.as_bytes().to_vec();
        b.run(&gate::wire_decode_sparse_case(), || {
            let mut r = BitReader::new(&bytes);
            decode_payload(&mut r, d).unwrap();
        });

        let d = 2_000usize;
        let mut comp = compress::from_spec("qsgd:16").unwrap();
        let mut out = Update::new_dense(d);
        let x: Vec<f32> = (0..d).map(|i| ((i % 31) as f32 - 15.0) * 0.05).collect();
        comp.compress(&x, &mut rng, &mut out);
        let mut w = BitWriter::new();
        b.run(&gate::wire_encode_qsgd_case(), || {
            w.clear();
            comp.encode_payload(&out, &mut w);
        });
        let bytes = w.as_bytes().to_vec();
        b.run(&gate::wire_decode_qsgd_case(), || {
            let mut r = BitReader::new(&bytes);
            decode_payload(&mut r, d).unwrap();
        });
    }

    // --- composed compressor: quantization ∘ sparsification -------------------
    // The `qsgd:16(top_k:100)` pipeline at the RCV1 dimension: the scan
    // case prices the full compress (top-100 selection + per-coordinate
    // stochastic quantization on the kept values); the encode/decode pair
    // prices the native `TAG_COMPOSED` payload (gamma deltas + sign bits
    // + gamma levels — 22 bits/coordinate vs the sparse frame's 48).
    {
        use memsgd::compress::elias::{decode_payload, BitReader, BitWriter};
        use memsgd::compress::Compressor;

        let d = 47_236usize;
        let mut comp = compress::from_spec("qsgd:16(top_k:100)").unwrap();
        let mut rng = Prng::new(29);
        let mut out = Update::new_sparse(d);
        let x: Vec<f32> = (0..d).map(|i| ((i % 101) as f32 - 50.0) * 0.01).collect();
        b.run(&gate::composed_scan_case(), || {
            comp.compress(&x, &mut rng, &mut out);
        });
        // `encode_payload` rides the operator's native level scratch from
        // the last compress, so the frame is the 22-bit/coordinate form.
        let mut w = BitWriter::new();
        b.run(&gate::composed_encode_case(), || {
            w.clear();
            comp.encode_payload(&out, &mut w);
        });
        let bytes = w.as_bytes().to_vec();
        b.run(&gate::composed_decode_case(), || {
            let mut r = BitReader::new(&bytes);
            decode_payload(&mut r, d).unwrap();
        });
    }

    // --- TCP round trip: encode → localhost socket → decode -------------------
    // The cluster runtime's per-message data-plane cost: payload encode,
    // 4-byte length framing, one kernel-socket hop, frame read, payload
    // decode. Same two payload families as the codec cases above, so the
    // delta against those rows isolates the framing + syscall overhead.
    // Frames are a few KB — far below the socket buffers, so the
    // single-threaded write-then-read never blocks.
    {
        use memsgd::compress::elias::{decode_payload, BitReader, BitWriter};
        use memsgd::compress::Compressor;
        use memsgd::coordinator::net::{read_frame, socket_pair, write_frame};
        use memsgd::coordinator::transport::MAX_FRAME_BYTES;

        let (mut tx, mut rx) = socket_pair().expect("localhost socket pair");
        let mut rng = Prng::new(13);
        let mut w = BitWriter::new();

        let d = 47_236usize;
        let mut comp = compress::from_spec("top_k:10").unwrap();
        let mut out = Update::new_sparse(d);
        let x: Vec<f32> = (0..d).map(|i| ((i % 89) as f32 - 44.0) * 0.01).collect();
        comp.compress(&x, &mut rng, &mut out);
        b.run(&gate::tcp_roundtrip_sparse_case(), || {
            w.clear();
            comp.encode_payload(&out, &mut w);
            write_frame(&mut tx, w.as_bytes()).unwrap();
            let frame = read_frame(&mut rx, MAX_FRAME_BYTES).unwrap();
            let mut r = BitReader::new(&frame);
            decode_payload(&mut r, d).unwrap();
        });

        let d = 2_000usize;
        let mut comp = compress::from_spec("qsgd:16").unwrap();
        let mut out = Update::new_dense(d);
        let x: Vec<f32> = (0..d).map(|i| ((i % 37) as f32 - 18.0) * 0.05).collect();
        comp.compress(&x, &mut rng, &mut out);
        b.run(&gate::tcp_roundtrip_qsgd_case(), || {
            w.clear();
            comp.encode_payload(&out, &mut w);
            write_frame(&mut tx, w.as_bytes()).unwrap();
            let frame = read_frame(&mut rx, MAX_FRAME_BYTES).unwrap();
            let mut r = BitReader::new(&frame);
            decode_payload(&mut r, d).unwrap();
        });
    }

    // --- server dispatch: one poll wakeup's worth of uploads ------------------
    // The event-driven cluster server's per-wakeup data-plane cost: a
    // readiness chunk carrying 8 framed top-10 uploads (one per node of
    // an 8-node round) pushed through the resumable `FrameAssembler`
    // and the typed wire decoder. The delta against the codec rows
    // isolates the reassembly + dispatch overhead the poll backend adds
    // over raw decode.
    {
        use memsgd::compress::elias::BitWriter;
        use memsgd::compress::Compressor;
        use memsgd::coordinator::net::FrameAssembler;
        use memsgd::coordinator::transport::{decode_msg, encode_upload, MAX_FRAME_BYTES};

        let d = 47_236usize;
        let mut comp = compress::from_spec("top_k:10").unwrap();
        let mut rng = Prng::new(17);
        let mut out = Update::new_sparse(d);
        let x: Vec<f32> = (0..d).map(|i| ((i % 83) as f32 - 41.0) * 0.01).collect();
        comp.compress(&x, &mut rng, &mut out);
        let mut chunk = Vec::new();
        for node in 0..8u32 {
            let mut w = BitWriter::new();
            encode_upload(&mut w, 0, node, 1_234, &*comp, &out);
            let frame = w.as_bytes();
            chunk.extend_from_slice(&(frame.len() as u32).to_be_bytes());
            chunk.extend_from_slice(frame);
        }
        b.run(&gate::server_dispatch_case(), || {
            let mut asm = FrameAssembler::new(MAX_FRAME_BYTES);
            asm.feed(&chunk).unwrap();
            let mut seen = 0usize;
            while let Some(frame) = asm.next_frame() {
                decode_msg(&frame, d).unwrap();
                seen += 1;
            }
            assert_eq!(seen, 8);
        });
    }

    // --- degraded-quorum fold + SNAPSHOT codec (the failure hot paths) --------
    // The drop-round policy's steady-state server cost: decode the 7
    // live top-10 uploads of an 8-slot round (the dead slot skipped in
    // node-id order, exactly as `serve_sync_protocol` skips it), fold
    // them into the sparse aggregate, frame the quorum-mean (1/7)
    // pre-scaled broadcast, and apply it to the server iterate. Plus
    // the rejoin/restart control-plane frames: SNAPSHOT encode (the
    // restarted server re-syncing a replica) and decode (the rejoining
    // worker seeding its model) of the full dense iterate at the RCV1
    // dimension — the one O(d)-payload message in the failure protocol,
    // priced here so resync cost stays visible in the baseline.
    {
        use memsgd::compress::elias::BitWriter;
        use memsgd::compress::Compressor;
        use memsgd::coordinator::transport::{
            decode_msg, encode_broadcast, encode_snapshot, encode_upload, WireMsg,
        };
        use std::collections::BTreeMap;

        let d = 47_236usize;
        let mut comp = compress::from_spec("top_k:10").unwrap();
        let mut rng = Prng::new(23);
        let mut frames = Vec::new();
        for node in 0..8u32 {
            if node == 3 {
                continue; // the dead slot: drop-round's fold never sees it
            }
            let x: Vec<f32> = (0..d)
                .map(|i| (((i + node as usize * 173) % 103) as f32 - 51.0) * 0.01)
                .collect();
            let mut out = Update::new_sparse(d);
            comp.compress(&x, &mut rng, &mut out);
            let mut w = BitWriter::new();
            encode_upload(&mut w, 0, node, 1_234, &*comp, &out);
            frames.push(w.as_bytes().to_vec());
        }
        let mut x = vec![0.01f32; d];
        let mut agg: BTreeMap<u32, f32> = BTreeMap::new();
        let mut bc = Update::new_sparse(d);
        let mut w = BitWriter::new();
        b.run(&gate::server_fold_quorum_case(), || {
            agg.clear();
            for frame in &frames {
                match decode_msg(frame, d).unwrap().msg {
                    WireMsg::Upload { update: Update::Sparse(sv), .. } => {
                        for (&j, &vj) in sv.idx.iter().zip(&sv.val) {
                            *agg.entry(j).or_insert(0.0) += vj;
                        }
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
            let scale = 1.0 / 7.0f32;
            let sv = bc.sparse_mut(d);
            for (&j, &vj) in agg.iter() {
                sv.push(j, vj * scale);
            }
            w.clear();
            encode_broadcast(&mut w, 0, &bc);
            for (&j, &vj) in agg.iter() {
                x[j as usize] -= vj * scale;
            }
        });

        let model = Update::Dense((0..d).map(|i| ((i % 71) as f32 - 35.0) * 1e-3).collect());
        b.run(&gate::snapshot_encode_case(), || {
            w.clear();
            encode_snapshot(&mut w, 24, &model);
        });
        let bytes = w.as_bytes().to_vec();
        b.run(&gate::snapshot_decode_case(), || {
            decode_msg(&bytes, d).unwrap();
        });
    }

    // --- ring merge: the server-free engines' per-round fold ------------------
    // One all-reduce round's aggregation work at the last ring position:
    // reset the partial, fold one top-10 contribution per node of an
    // 8-node ring, frame the aggregate for the payload codec. The merge
    // table keeps the sparse fold O(total nnz); the mixed case injects
    // one dense contribution mid-fold to price the spill path (the
    // structural fix for PR 7's mixed sparse/dense aggregation drop),
    // which turns the remaining folds into O(nnz) dense scatters plus
    // one O(d) frame fill.
    {
        use memsgd::coordinator::experiment::RingPartial;

        let d = 47_236usize;
        let w_nodes = 8usize;
        let mut comp = compress::from_spec("top_k:10").unwrap();
        let mut rng = Prng::new(19);
        let mut updates = Vec::with_capacity(w_nodes);
        for node in 0..w_nodes {
            let x: Vec<f32> = (0..d)
                .map(|i| (((i + node * 131) % 101) as f32 - 50.0) * 0.01)
                .collect();
            let mut out = Update::new_sparse(d);
            comp.compress(&x, &mut rng, &mut out);
            updates.push(out);
        }

        let mut partial = RingPartial::new(d);
        b.run(&gate::ring_merge_sparse_case(w_nodes), || {
            partial.begin();
            for u in &updates {
                partial.fold(u);
            }
            assert!(matches!(partial.fill_update(), Update::Sparse(_)));
        });

        let dense: Vec<f32> = (0..d).map(|i| ((i % 59) as f32 - 29.0) * 1e-4).collect();
        let dense_u = Update::Dense(dense);
        b.run(&gate::ring_merge_mixed_case(w_nodes), || {
            partial.begin();
            for (k, u) in updates.iter().enumerate() {
                partial.fold(u);
                if k == w_nodes / 2 {
                    partial.fold(&dense_u);
                }
            }
            assert!(matches!(partial.fill_update(), Update::Dense(_)));
        });
    }

    // --- weighted averaging overhead ------------------------------------------
    {
        let d = 2_000;
        let mut avg = memsgd::optim::WeightedAverage::new(d, 2_000.0);
        let x = vec![0.5f32; d];
        b.run("weighted avg update dense d=2000", || {
            avg.update(&x);
        });
    }

    b.finish();
    // Maintain the perf trajectory / CI baseline: every run merges its
    // rows (deduped by case, latest wins — rewriting is idempotent, so
    // this is safe even when the MEMSGD_BENCH_JSON hook already wrote
    // to the same path in finish()). CI points MEMSGD_BENCH_JSON at a
    // separate fresh-rows-only file for the bench-gate comparison.
    match b.write_json("BENCH_hot_path.json") {
        Ok(()) => println!("perf rows merged -> BENCH_hot_path.json"),
        Err(e) => eprintln!("could not write BENCH_hot_path.json: {e}"),
    }

    // Sparse-pipeline payoff, printed for EXPERIMENTS.md (the CI gate
    // enforces the B=1 local-step and a=100 sync ratios via
    // `memsgd bench-gate`):
    let p50 = |name: &str| b.results.iter().find(|m| m.name == name).map(|m| m.p50_ns);
    for bsz in [1usize, 8, 64] {
        let dense = p50(&gate::local_step_dense_case(bsz));
        let sparse = p50(&gate::local_step_sparse_case(bsz));
        if let (Some(dense), Some(sparse)) = (dense, sparse) {
            println!("sparse local-step speedup B={bsz} at d/nnz~470: {:.1}x", dense / sparse);
        }
    }
    for a in [100usize, 1_000, 10_000] {
        let dense = p50(&gate::phase_sync_dense_case());
        let active = p50(&gate::phase_sync_active_case(a));
        if let (Some(dense), Some(active)) = (dense, active) {
            println!("active-set sync speedup a={a} at d=47236: {:.1}x", dense / active);
        }
    }

    // The §7 acceptance check, printed for EXPERIMENTS.md:
    let ratio_cases: Vec<(&str, f64)> = b
        .results
        .iter()
        .filter(|m| m.name.contains("d=2000") && m.name.contains("step"))
        .map(|m| (m.name.trim(), m.mean_ns))
        .collect();
    if let (Some(mem), Some(sgd)) = (
        ratio_cases.iter().find(|c| c.0.contains("memsgd")),
        ratio_cases.iter().find(|c| c.0.contains("vanilla")),
    ) {
        println!(
            "\nDESIGN §7 check: memsgd/vanilla iteration ratio = {:.2} (target <= 2.0)",
            mem.1 / sgd.1
        );
    }
}
