//! Section 2.2 ablation bench: the variance blow-up of unbiased
//! sparsification, measured, plus the convergence consequence — the
//! motivating argument for the memory mechanism.
//!
//! Run: `cargo bench --bench ablation_section22`

use memsgd::experiments::extensions;
use memsgd::experiments::Which;
use memsgd::util::bench::Bench;
use std::time::Instant;

fn main() {
    let scale: usize = std::env::var("MEMSGD_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let mut b = Bench::slow("ablation_section22");

    for which in [Which::Epsilon, Which::Rcv1] {
        let steps = 10_000;
        let started = Instant::now();
        let res = extensions::section22(which, scale, steps, 1).expect("section22 failed");
        b.record(
            &format!("section22 {} (4 runs x {steps})", which.name()),
            started.elapsed(),
            4 * steps,
        );

        let base = res.variances[0].1;
        let blown = res.variances[1].1;
        let measured = blown / base.max(1e-12);
        println!(
            "  {}: measured variance blow-up {measured:.0}x (paper predicts ~d/k = {:.0}x)",
            which.name(),
            res.predicted_blowup
        );
        // The §2.2 claim: the blow-up is Θ(d/k). Accept a 4× band — the
        // reference variance subtracts ∇f and the constant differs with
        // data geometry, but the order must match.
        assert!(
            measured > res.predicted_blowup / 4.0 && measured < res.predicted_blowup * 4.0,
            "blow-up {measured:.0}x out of band vs {:.0}x",
            res.predicted_blowup
        );

        let find = |pat: &str| {
            res.records
                .iter()
                .find(|r| r.method.contains(pat))
                .unwrap_or_else(|| panic!("missing {pat}"))
        };
        let sgd = find("sgd").final_loss();
        let unbiased = find("unbiased").final_loss();
        let mem = find("memsgd(rand").final_loss();
        println!("  {}: final loss sgd {sgd:.4} | unbiased {unbiased:.4} | mem {mem:.4}", which.name());
        // Memory closes the gap the unbiased scheme cannot.
        assert!(mem < unbiased, "memory must beat the unbiased scheme");
        assert!(
            (mem - sgd).abs() < 0.1,
            "Mem-SGD should track vanilla SGD: {mem:.4} vs {sgd:.4}"
        );
    }
    b.finish();
}
