//! Figure 6 (extension) regeneration bench: time-to-accuracy of the
//! distributed methods on real link profiles — the claim the paper's
//! abstract makes ("better scalability for distributed applications")
//! priced on 1GbE / 10GbE / 100Gb-IB.
//!
//! Run: `cargo bench --bench figure6_network`

use memsgd::coordinator::LocalUpdate;
use memsgd::experiments::extensions;
use memsgd::experiments::Which;
use memsgd::util::bench::Bench;
use std::time::Instant;

fn main() {
    let scale: usize = std::env::var("MEMSGD_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let mut b = Bench::slow("figure6_network");

    for which in [Which::Epsilon, Which::Rcv1] {
        let rounds = 1_200;
        let workers = 8;
        let started = Instant::now();
        let res =
            extensions::figure6_network(which, scale, rounds, workers, LocalUpdate::default(), 1)
                .expect("figure6 driver failed");
        b.record(
            &format!("figure6 {} ({} cells)", which.name(), res.cells.len()),
            started.elapsed(),
            rounds * workers,
        );
        println!("{}", res.table());

        let secs = |m: &str, net: &str| {
            res.cells
                .iter()
                .find(|c| c.method.contains(m) && c.network == net)
                .and_then(|c| c.seconds_to_target)
        };
        // Reproduction check 1: on the slow link, if both reach the
        // target, sparse must be faster than dense by a wide margin.
        if let (Some(sparse), Some(dense)) = (secs("top_k", "1GbE"), secs("identity", "1GbE")) {
            let factor = dense / sparse;
            println!("  {}: 1GbE dense/top-k time factor = {factor:.1}x", which.name());
            assert!(
                factor > 3.0,
                "sparse should win clearly on 1GbE: {factor:.2}x"
            );
        }
        // Reproduction check 2: the dense method's comm share collapses
        // on the fast link — the bottleneck story is network-dependent.
        let frac = |m: &str, net: &str| {
            res.cells
                .iter()
                .find(|c| c.method.contains(m) && c.network == net)
                .map(|c| c.comm_fraction)
                .unwrap()
        };
        assert!(frac("identity", "1GbE") > frac("identity", "100Gb-IB"));
        assert!(frac("top_k", "1GbE") < frac("identity", "1GbE"));
    }
    b.finish();
}
