//! Figure 3 regeneration bench: Mem-SGD vs QSGD in iterations and bits,
//! asserting the paper's headline — same-rate convergence at one-to-two
//! orders of magnitude fewer communicated bits.
//!
//! Run: `cargo bench --bench figure3_qsgd`

use memsgd::experiments::{self, Which};
use memsgd::metrics::fmt_bits;
use memsgd::util::bench::Bench;
use std::time::Instant;

fn main() {
    let scale: usize = std::env::var("MEMSGD_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let mut b = Bench::slow("figure3_qsgd");

    for which in [Which::Epsilon, Which::Rcv1] {
        let started = Instant::now();
        let records = experiments::figure3(which, scale, 2, 10, Some(1.0), 1)
            .expect("figure3 driver failed");
        b.record(
            &format!("figure3 {} (4 series)", which.name()),
            started.elapsed(),
            records.iter().map(|r| r.steps).sum(),
        );

        let top = &records[0];
        println!("  {} loss/bits at equal iterations:", which.name());
        for r in &records {
            println!(
                "    {:<18} loss {:.4}  bits {:>10}",
                r.method,
                r.final_loss(),
                fmt_bits(r.total_bits)
            );
        }
        // Convergence parity with the 8-bit variant...
        let q8 = records.iter().find(|r| r.method.contains("8bit")).unwrap();
        assert!(
            top.final_loss() < q8.final_loss() + 0.05,
            "top-k should track 8-bit QSGD per iteration"
        );
        // ...at a large bit discount. On dense epsilon the paper reports
        // ~2 orders of magnitude (we assert >= 10x vs every variant); on
        // RCV1 QSGD gets the paper's generous sparsity-aware accounting
        // (Appendix B, d_eff ≈ mean row nnz), which narrows the gap — we
        // assert Mem-SGD is no worse than the convergence-matched 8-bit
        // variant.
        for q in &records[1..] {
            let ratio = q.total_bits as f64 / top.total_bits.max(1) as f64;
            println!("    bits({}) / bits({}) = {ratio:.2}x", q.method, top.method);
            if which == Which::Epsilon {
                assert!(ratio > 10.0, "expected >=10x bit reduction vs {}", q.method);
            }
        }
        if which == Which::Rcv1 {
            assert!(
                top.total_bits < q8.total_bits,
                "top-k should still undercut the convergence-matched 8-bit QSGD"
            );
        }
    }
    b.finish();
}
