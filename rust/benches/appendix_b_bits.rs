//! Appendix-B bit-accounting cross-check: the paper's closed-form cost
//! estimates (QSGD `min((log₂s+1)d, 3s(s+√d)+32)`; top-k footnote 5
//! `k(32 + log₂ d)`) against **actually encoded** Elias bitstreams
//! (`compress::elias`). Figure 3's x-axis rests on these formulas, so
//! the repro verifies they are honest for both sides.
//!
//! Run: `cargo bench --bench appendix_b_bits`

use memsgd::compress::elias::{encode_qsgd, encode_sparse, BitWriter};
use memsgd::compress::{self, Compressor, Qsgd, Update};
use memsgd::util::bench::Bench;
use memsgd::util::prng::Prng;
use memsgd::util::stats;

fn main() {
    let mut b = Bench::new("appendix_b_bits");
    let mut rng = Prng::new(1);

    println!("\n-- top-k: footnote-5 formula vs exact Elias payload --");
    for &(d, k) in &[(2_000usize, 1usize), (2_000, 10), (47_236, 10), (47_236, 100)] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut comp = compress::from_spec(&format!("top_k:{k}")).unwrap();
        let mut out = Update::new_sparse(d);
        let formula = comp.compress(&x, &mut rng, &mut out);
        let mut w = BitWriter::new();
        let exact = match &out {
            Update::Sparse(s) => encode_sparse(s, &mut w),
            _ => unreachable!(),
        };
        let ratio = formula as f64 / exact as f64;
        println!(
            "  top_{k:<4} d={d:<6} formula {formula:>8} bits   elias {exact:>8} bits   formula/exact {ratio:.2}",
        );
        // The formula must be within 2× of the real encoder either way —
        // otherwise Figure 3's axes would be distorted.
        assert!(
            (0.5..=2.5).contains(&ratio),
            "footnote-5 accounting off by {ratio:.2}x at d={d} k={k}"
        );
        b.run(&format!("elias encode top_{k} d={d}"), || {
            let mut w = BitWriter::new();
            if let Update::Sparse(s) = &out {
                encode_sparse(s, &mut w);
            }
        });
    }

    println!("\n-- QSGD: Theorem-3.2 estimate vs exact Elias payload --");
    for &(d, s_levels) in &[(2_000usize, 4u32), (2_000, 16), (2_000, 256), (47_236, 16)] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut q = Qsgd::new(s_levels);
        let mut out = Update::new_dense(d);
        let formula = q.compress(&x, &mut rng, &mut out);
        // Reconstruct the level integers the quantizer emitted.
        let norm = stats::l2_norm(&x) as f32;
        let dense = out.to_dense(d);
        let levels: Vec<i32> = dense
            .iter()
            .map(|&v| (v / norm * s_levels as f32).round() as i32)
            .collect();
        let mut w = BitWriter::new();
        let exact = encode_qsgd(norm, &levels, &mut w);
        let ratio = formula as f64 / exact as f64;
        let bbits = (s_levels as f64).log2() as u32;
        println!(
            "  qsgd {bbits}-bit d={d:<6} formula {formula:>8} bits   elias {exact:>8} bits   formula/exact {ratio:.2}",
        );
        // Findings (asserted as a sanity band, reported above exactly):
        // * s=4: formula UNDER-charges 2.1× — Theorem 3.2's constant
        //   assumes ~3 bits/nonzero, real γ-coded gaps cost ~7. Makes
        //   Figure 3 conservative (QSGD looks cheaper than it is).
        // * s=256: the naive branch OVER-charges 1.5× vs a real Elias
        //   stream — i.e. QSGD-with-Elias beats the formula's min. The
        //   headline check below therefore re-tests the two-orders claim
        //   with exact payloads on BOTH sides.
        assert!(
            (0.3..=3.0).contains(&ratio),
            "Appendix-B formula wildly off ({ratio:.2}x) at d={d} s={s_levels}"
        );
        b.run(&format!("elias encode qsgd s={s_levels} d={d}"), || {
            let mut w = BitWriter::new();
            encode_qsgd(norm, &levels, &mut w);
        });
    }

    // Headline sanity: at comparable accuracy (top-1 vs 8-bit QSGD on
    // d=2000, Figure 3), the *exact* payloads must still differ by two
    // orders of magnitude.
    {
        let d = 2_000;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut top = compress::from_spec("top_k:1").unwrap();
        let mut out = Update::new_sparse(d);
        top.compress(&x, &mut rng, &mut out);
        let mut w = BitWriter::new();
        let top_exact = match &out {
            Update::Sparse(s) => encode_sparse(s, &mut w),
            _ => unreachable!(),
        };
        let mut q = Qsgd::new(256);
        let mut qout = Update::new_dense(d);
        q.compress(&x, &mut rng, &mut qout);
        let norm = stats::l2_norm(&x) as f32;
        let levels: Vec<i32> = qout
            .to_dense(d)
            .iter()
            .map(|&v| (v / norm * 256.0).round() as i32)
            .collect();
        let mut w2 = BitWriter::new();
        let qsgd_exact = encode_qsgd(norm, &levels, &mut w2);
        let factor = qsgd_exact as f64 / top_exact as f64;
        println!("\n  exact per-iteration payload: top-1 {top_exact} bits, qsgd-8bit {qsgd_exact} bits — {factor:.0}x");
        assert!(factor > 100.0, "headline two-orders claim broke: {factor:.0}x");
    }

    b.finish();
}
