//! Figure 2 regeneration bench: end-to-end convergence runs per method
//! on both datasets, reporting wall time per run and the reproduced
//! convergence ordering (top-k ≥ rand-k, Mem-SGD ≈ SGD, a=1 blows up).
//!
//! Run: `cargo bench --bench figure2_convergence`
//! (Scaled down via MEMSGD_BENCH_SCALE, default 200 → n = 2000 / 3386.)

use memsgd::experiments::{self, Which};
use memsgd::util::bench::Bench;
use std::time::Instant;

fn main() {
    let scale: usize = std::env::var("MEMSGD_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let mut b = Bench::slow("figure2_convergence");

    for which in [Which::Epsilon, Which::Rcv1] {
        let started = Instant::now();
        let records =
            experiments::figure2(which, scale, 2, 10, 1).expect("figure2 driver failed");
        let elapsed = started.elapsed();
        b.record(
            &format!("figure2 {} (all {} series)", which.name(), records.len()),
            elapsed,
            records.iter().map(|r| r.steps).sum(),
        );

        // Reproduction checks (the paper's qualitative claims).
        let find = |pat: &str| {
            records
                .iter()
                .find(|r| r.method.contains(pat))
                .unwrap_or_else(|| panic!("missing series {pat}"))
        };
        let sgd = find("sgd");
        let k0 = which.ks()[0];
        let topk = find(&format!("memsgd(top_{k0})"));
        let randk = find(&format!("memsgd(rand_{k0})"));
        let nodelay = find("without delay");
        println!(
            "  {}: sgd {:.4} | top_{k0} {:.4} | rand_{k0} {:.4} | a=1 {:.4}",
            which.name(),
            sgd.final_loss(),
            topk.final_loss(),
            randk.final_loss(),
            nodelay.final_loss()
        );
        assert!(
            topk.final_loss() <= randk.final_loss() + 1e-3,
            "top-k should beat rand-k"
        );
        assert!(
            topk.final_loss() <= sgd.final_loss() + 0.05,
            "Mem-SGD top-k should match SGD"
        );
        // The paper: "setting a = 1 ... dramatically hurts the memory and
        // requires time to recover from the high initial learning rate".
        // The damage shows in the early/worst part of the curve (on
        // epsilon it also never recovers within the budget).
        let worst = |r: &memsgd::metrics::RunRecord| {
            r.curve.iter().map(|p| p.loss).fold(f64::MIN, f64::max)
        };
        assert!(
            worst(nodelay) > 2.0 * worst(topk),
            "a=1 ablation should visibly hurt early: worst {} vs {}",
            worst(nodelay),
            worst(topk)
        );
        let comm_ratio = sgd.total_bits as f64 / topk.total_bits as f64;
        println!(
            "  {}: communication reduction sgd/top_{k0} = {comm_ratio:.0}x",
            which.name()
        );
    }
    b.finish();
}
