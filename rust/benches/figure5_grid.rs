//! Figure 5 regeneration bench: the gamma0 grid search behind Figure 3's
//! tuned learning rates, per dataset, asserting that the winner is an
//! interior point of the grid (neither extreme).
//!
//! Run: `cargo bench --bench figure5_grid`

use memsgd::experiments::{self, Which};
use memsgd::util::bench::Bench;
use std::time::Instant;

fn main() {
    let mut b = Bench::slow("figure5_grid");
    for which in [Which::Epsilon, Which::Rcv1] {
        let started = Instant::now();
        let res = experiments::figure5(which, 400, 2_000, 1).expect("grid failed");
        b.record(
            &format!("figure5 {} ({} cells)", which.name(), res.cells.len()),
            started.elapsed(),
            res.cells.len(),
        );
        println!("{}", res.table());
        for method in res.methods() {
            let best = res.best(&method).unwrap();
            let grid = memsgd::grid::default_gamma0_grid();
            assert!(
                best.gamma0 > grid[0] && best.gamma0 < *grid.last().unwrap(),
                "{method}: winner {} sits on the grid edge",
                best.gamma0
            );
        }
    }
    b.finish();
}
