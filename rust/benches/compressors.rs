//! Microbenchmarks of the compression operators — the per-iteration
//! cost that must stay negligible next to the gradient (the paper's
//! premise is that compression is cheap relative to communication).
//!
//! Run: `cargo bench --bench compressors`

use memsgd::compress::{self, Update};
use memsgd::util::bench::Bench;
use memsgd::util::prng::Prng;

fn main() {
    let mut b = Bench::new("compressors");
    let mut rng = Prng::new(1);

    for &d in &[2_000usize, 47_236] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        for spec in [
            "top_k:1",
            "top_k:10",
            "top_k:100",
            "rand_k:10",
            "random_p:0.5",
            "qsgd:16",
            "identity",
        ] {
            let mut comp = compress::from_spec(spec).unwrap();
            let mut out = Update::new_sparse(d);
            let mut r = Prng::new(2);
            b.run(&format!("{spec:<14} d={d}"), || {
                comp.compress(&x, &mut r, &mut out);
            });
        }
    }

    // Transformer-scale selection (the e2e driver's per-step cost).
    {
        let d = 928_000usize;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut comp = compress::from_spec("top_k:1000").unwrap();
        let mut out = Update::new_sparse(d);
        let mut r = Prng::new(7);
        b.run("top_k:1000     d=928000 (e2e shape)", || {
            comp.compress(&x, &mut r, &mut out);
        });
    }

    // The dominant cost inside top-k: the heap-select engine vs full sort.
    for &d in &[2_000usize, 47_236] {
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut scratch: Vec<u32> = Vec::new();
        b.run(&format!("heap-select k=10 d={d}"), || {
            memsgd::util::select::top_k_indices(&x, 10, &mut scratch);
        });
        let mut idx: Vec<u32> = (0..d as u32).collect();
        b.run(&format!("full-sort     k=10 d={d}"), || {
            idx.sort_by(|&a, &b| {
                x[b as usize]
                    .abs()
                    .partial_cmp(&x[a as usize].abs())
                    .unwrap()
            });
        });

        // §Perf iteration 7 evidence: two-pass (SIMD v-build + scalar
        // scan) vs one-pass fused (scalar everything). The fused form
        // loses — this bench is the recorded justification for the
        // revert in optim/memsgd.rs.
        let m: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut v = vec![0.0f32; d];
        let mut heap: Vec<u64> = Vec::new();
        b.run(&format!("2-pass build+select  k=10 d={d}"), || {
            for ((vi, &mi), &gi) in v.iter_mut().zip(&m).zip(&x) {
                *vi = mi + 0.01 * gi;
            }
            memsgd::util::select::top_k_indices_with_heap(&v, 10, &mut heap, &mut scratch);
        });
        b.run(&format!("fused build+select   k=10 d={d}"), || {
            memsgd::util::select::top_k_fused(&m, &x, 0.01, &mut v, 10, &mut heap, &mut scratch);
        });
    }
    b.finish();
}
