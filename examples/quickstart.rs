//! Quickstart: Mem-SGD in ~30 lines.
//!
//! Trains L2-regularized logistic regression on a dense synthetic
//! dataset three ways — vanilla SGD, Mem-SGD with top-1 sparsification,
//! and the unbiased rand-1 baseline the paper's Section 2.2 warns about
//! — and prints the loss curves plus the communication bill.
//!
//! Run: `cargo run --release --example quickstart`

use memsgd::coordinator::train::{self, TrainConfig};
use memsgd::data::synthetic;
use memsgd::metrics::summary_table;

fn main() -> anyhow::Result<()> {
    // A small epsilon-like problem: n = 4000 samples, d = 500 features.
    let data = synthetic::epsilon_like(4_000, 500, 42);
    println!("dataset: {} ({} samples, {} features)\n", data.name, data.n(), data.d());

    let mut records = Vec::new();
    for method in ["sgd", "memsgd:top_k:1", "sgd:unbiased_rand_k:1"] {
        // Theorem 2.4 stepsizes: η_t = γ/(λ(t+a)) with a = d/k.
        let cfg = TrainConfig {
            method: method.into(),
            steps: 2 * data.n(), // two epochs
            eval_points: 12,
            seed: 7,
            ..TrainConfig::default()
        }
        .with_paper_schedule(data.d(), data.n(), 2.0, 1.0)?;
        let record = train::run(&data, &cfg)?;
        println!(
            "{:<24} final loss {:.4}   transmitted {}",
            record.method,
            record.final_loss(),
            memsgd::metrics::fmt_bits(record.total_bits)
        );
        records.push(record);
    }

    println!("\n{}", summary_table(&records));
    println!(
        "Mem-SGD top-1 matches SGD's loss while sending ~{}x fewer bits;\n\
         the unbiased rand-1 baseline pays the d/k variance blow-up of §2.2.",
        records[0].total_bits / records[1].total_bits.max(1)
    );
    Ok(())
}
