//! Quickstart: the unified `Experiment` API in ~40 lines.
//!
//! One typed builder drives every training topology in the crate. This
//! example trains L2-regularized logistic regression three ways —
//! vanilla SGD, Mem-SGD with top-1 sparsification, and the unbiased
//! rand-1 baseline the paper's Section 2.2 warns about — then reruns
//! Mem-SGD on a 4-worker shared-memory topology, all through the same
//! `Experiment::new(backend).method(..).topology(..).run()` chain.
//!
//! Migrating from the deprecated string-spec drivers:
//!
//! | old call | new builder chain |
//! |---|---|
//! | `train::run(&data, &TrainConfig { method: "memsgd:top_k:1".into(), .. })` | `Experiment::new(model).method(MethodSpec::mem_top_k(1)).run()?` |
//! | `parallel::run(&data, &ParallelConfig { workers: 4, .. })` | `.topology(Topology::SharedMemory { workers: 4 }).run()?` |
//! | `distributed::run(&data, &DistributedConfig { workers: 8, .. })` | `.topology(Topology::ParamServerSync { nodes: 8 }).run()?` |
//! | `async_dist::run(&data, &AsyncConfig { .. })` | `.topology(Topology::ParamServerAsync { nodes: 8, net }).run()?` |
//!
//! The old entry points still work (they are shims over the same
//! engines), and `MethodSpec::parse("memsgd:top_k:1")` covers the
//! string edge for CLIs and config files.
//!
//! Run: `cargo run --release --example quickstart`

use memsgd::coordinator::{Experiment, MethodSpec, Topology};
use memsgd::data::synthetic;
use memsgd::metrics::summary_table;
use memsgd::models::LogisticModel;

fn main() -> anyhow::Result<()> {
    // A small epsilon-like problem: n = 4000 samples, d = 500 features.
    let data = synthetic::epsilon_like(4_000, 500, 42);
    let lam = 1.0 / data.n() as f64;
    println!("dataset: {} ({} samples, {} features)\n", data.name, data.n(), data.d());

    let mut records = Vec::new();
    for method in [
        MethodSpec::Sgd,
        MethodSpec::mem_top_k(1),
        MethodSpec::SgdUnbiasedRandK { k: 1 },
    ] {
        // Theorem 2.4 stepsizes: η_t = γ/(λ(t+a)) with a = d/k.
        let schedule = method.paper_schedule(data.d(), data.n(), 2.0, 1.0, None);
        let record = Experiment::new(LogisticModel::new(&data, lam))
            .dataset(&data.name)
            .method(method)
            .schedule(schedule)
            .steps(2 * data.n()) // two epochs
            .eval_points(12)
            .seed(7)
            .run()?;
        println!(
            "{:<24} final loss {:.4}   transmitted {}",
            record.method,
            record.final_loss(),
            memsgd::metrics::fmt_bits(record.total_bits)
        );
        records.push(record);
    }

    // Same method, different fabric: Algorithm 2's lock-free threads.
    // Only the `.topology(..)` line changes.
    let parallel = Experiment::new(LogisticModel::new(&data, lam))
        .dataset(&data.name)
        .method(MethodSpec::mem_top_k(1))
        .schedule(memsgd::optim::Schedule::constant(0.05))
        .topology(Topology::SharedMemory { workers: 4 })
        .steps(2 * data.n())
        .seed(7)
        .run()?;
    println!(
        "{:<24} final loss {:.4}   transmitted {}",
        parallel.method,
        parallel.final_loss(),
        memsgd::metrics::fmt_bits(parallel.total_bits)
    );
    records.push(parallel);

    println!("\n{}", summary_table(&records));
    println!(
        "Mem-SGD top-1 matches SGD's loss while sending ~{}x fewer bits;\n\
         the unbiased rand-1 baseline pays the d/k variance blow-up of §2.2.",
        records[0].total_bits / records[1].total_bits.max(1)
    );
    Ok(())
}
