//! Distributed Mem-SGD end to end: synchronous parameter-server rounds,
//! then the asynchronous variant under a network cost model — the
//! deployment story of the paper's §1/§5.
//!
//! Run: `cargo run --release --example distributed`
//!      `cargo run --release --example distributed -- --dataset rcv1 --workers-count 16`

use anyhow::Result;

use memsgd::coordinator::async_dist::{self, AsyncConfig};
use memsgd::coordinator::checkpoint::Checkpoint;
use memsgd::coordinator::distributed::{self, DistributedConfig};
use memsgd::compress;
use memsgd::experiments::{self, Which};
use memsgd::metrics::{fmt_bits, summary_table};
use memsgd::optim::{MemSgd, Schedule};
use memsgd::sim::network::{ComputeModel, NetworkModel};
use memsgd::util::cli::Args;
use memsgd::util::prng::Prng;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 100usize)?;
    let workers = args.get("workers-count", 8usize)?;
    let rounds = args.get("rounds", 2_000usize)?;
    let seed = args.get("seed", 1u64)?;
    let data = experiments::dataset(which, scale, seed);
    let k0 = which.ks()[0];

    println!(
        "== distributed Mem-SGD on {} (n={}, d={}, {} workers) ==\n",
        data.name,
        data.n(),
        data.d(),
        workers
    );

    // ---- 1. Synchronous parameter-server rounds, three wire formats.
    println!("-- synchronous rounds ({rounds}) --");
    let mut sync_records = Vec::new();
    for spec in [format!("top_k:{k0}"), "qsgd:16".into(), "identity".to_string()] {
        let cfg = DistributedConfig {
            workers,
            rounds,
            compressor: spec.clone(),
            schedule: Schedule::constant(0.5),
            eval_points: 8,
            lam: None,
            seed,
        };
        let rec = distributed::run(&data, &cfg)?;
        println!(
            "  {:<28} final loss {:.4}   upload {:>10}  broadcast {:>10}",
            rec.method,
            rec.final_loss(),
            fmt_bits(rec.extra["upload_bits"] as u64),
            fmt_bits(rec.extra["broadcast_bits"] as u64),
        );
        sync_records.push(rec);
    }

    // ---- 2. Asynchronous parameter server on a slow link: the sparse
    //         uploads keep the server NIC idle, dense ones queue.
    println!("\n-- asynchronous server, 1GbE, heterogeneous fleet --");
    let mean_coords = (data.nnz() as f64 / data.n() as f64).max(1.0);
    for spec in [format!("top_k:{k0}"), "identity".to_string()] {
        let cfg = AsyncConfig {
            workers,
            total_updates: rounds * workers,
            compressor: spec.clone(),
            schedule: Schedule::constant(0.5),
            network: NetworkModel::eth_1g(),
            compute: ComputeModel::new(1e-9, mean_coords),
            hetero: 0.5,
            eval_points: 8,
            lam: None,
            seed,
        };
        let (rec, stats) = async_dist::run(&data, &cfg)?;
        println!(
            "  {:<36} loss {:.4}  sim {:>8.3}s  staleness {:>5.1} (max {:>3})  link {:>5.1}%",
            rec.method,
            rec.final_loss(),
            stats.sim_seconds,
            stats.mean_staleness,
            stats.max_staleness,
            100.0 * stats.link_utilization,
        );
    }

    // ---- 3. Fault tolerance: checkpoint a sequential run mid-flight and
    //         resume bit-identically (what a preempted worker would do).
    println!("\n-- checkpoint / resume --");
    let d = data.d();
    let mut model = memsgd::models::LogisticModel::with_paper_lambda(&data);
    let mut opt = MemSgd::new(vec![0.0f32; d], compress::from_spec(&format!("top_k:{k0}"))?);
    let mut rng = Prng::new(seed);
    let mut grad = vec![0.0f32; d];
    use memsgd::models::GradBackend;
    for t in 0..500 {
        let i = rng.below(data.n());
        model.sample_grad(&opt.x, i, &mut grad);
        opt.step(&grad, 0.1, &mut rng);
        let _ = t;
    }
    let ck = Checkpoint::capture(&opt, &format!("top_k:{k0}"), &rng, None);
    let path = std::env::temp_dir().join("memsgd_distributed_example.ck");
    ck.save(&path)?;
    let (mut resumed, mut rng2, _) = Checkpoint::load(&path)?.restore()?;
    for _ in 500..1_000 {
        let i = rng2.below(data.n());
        model.sample_grad(&resumed.x, i, &mut grad);
        resumed.step(&grad, 0.1, &mut rng2);
    }
    println!(
        "  checkpointed at t=500 ({} bytes), resumed to t=1000, loss {:.4}",
        std::fs::metadata(&path)?.len(),
        model.full_loss(&resumed.x),
    );
    std::fs::remove_file(&path).ok();

    println!("\n{}", summary_table(&sync_records));
    Ok(())
}
