//! Distributed Mem-SGD end to end: synchronous parameter-server rounds,
//! the local-update schedule (`--batch`/`--local-steps`), then the
//! asynchronous variant under a network cost model — the deployment
//! story of the paper's §1/§5.
//!
//! Run: `cargo run --release --example distributed`
//!      `cargo run --release --example distributed -- --dataset rcv1 --workers-count 16`
//!      `cargo run --release --example distributed -- --batch 8 --local-steps 4`

use anyhow::Result;

use memsgd::compress::{self, CompressorSpec};
use memsgd::coordinator::checkpoint::Checkpoint;
use memsgd::coordinator::{Experiment, LocalUpdate, MethodSpec, Topology};
use memsgd::experiments::{self, Which};
use memsgd::metrics::{fmt_bits, summary_table};
use memsgd::models::LogisticModel;
use memsgd::optim::{MemSgd, Schedule};
use memsgd::sim::network::{ComputeModel, NetworkModel};
use memsgd::util::cli::Args;
use memsgd::util::prng::Prng;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 100usize)?;
    let workers = args.get("workers-count", 8usize)?;
    let rounds = args.get("rounds", 2_000usize)?;
    let seed = args.get("seed", 1u64)?;
    let data = experiments::dataset(which, scale, seed);
    let k0 = which.ks()[0];

    println!(
        "== distributed Mem-SGD on {} (n={}, d={}, {} workers) ==\n",
        data.name,
        data.n(),
        data.d(),
        workers
    );

    // ---- 1. Synchronous parameter-server rounds, three wire formats,
    //         all through the unified Experiment builder.
    println!("-- synchronous rounds ({rounds}) --");
    let lam = 1.0 / data.n() as f64;
    let mut sync_records = Vec::new();
    for comp in [
        CompressorSpec::TopK { k: k0 },
        CompressorSpec::Qsgd { levels: 16, eff: None },
        CompressorSpec::Identity,
    ] {
        let rec = Experiment::new(LogisticModel::new(&data, lam))
            .dataset(&data.name)
            .method(MethodSpec::mem(comp))
            .schedule(Schedule::constant(0.5))
            .topology(Topology::ParamServerSync { nodes: workers })
            .steps(rounds * workers)
            .eval_points(8)
            .seed(seed)
            .run()?;
        println!(
            "  {:<28} final loss {:.4}   upload {:>10}  broadcast {:>10}",
            rec.method,
            rec.final_loss(),
            fmt_bits(rec.extra["upload_bits"] as u64),
            fmt_bits(rec.extra["broadcast_bits"] as u64),
        );
        sync_records.push(rec);
    }

    // ---- 1b. Local-update scheduling: each node takes H = --local-steps
    //          error-compensated minibatch (--batch) steps between syncs,
    //          cutting the communicated bits by another factor of H at
    //          the same gradient budget.
    let local = LocalUpdate::new(args.get("batch", 4usize)?, args.get("local-steps", 4usize)?)?;
    println!(
        "\n-- local-update schedule (B={}, H={}) --",
        local.batch, local.sync_every
    );
    let budget = rounds * workers; // local steps, like the H=1 runs above
    let local_rec = Experiment::new(LogisticModel::new(&data, lam))
        .dataset(&data.name)
        .method(MethodSpec::mem_top_k(k0))
        .schedule(Schedule::constant(0.5))
        .topology(Topology::ParamServerSync { nodes: workers })
        .steps(budget)
        .eval_points(8)
        .seed(seed)
        .local_update(local)
        .run()?;
    let h1_upload = sync_records[0].extra["upload_bits"];
    println!(
        "  {:<28} final loss {:.4}   upload {:>10}  ({:.1}x fewer bits than H=1)",
        local_rec.method,
        local_rec.final_loss(),
        fmt_bits(local_rec.extra["upload_bits"] as u64),
        h1_upload / local_rec.extra["upload_bits"].max(1.0),
    );

    // ---- 2. Asynchronous parameter server on a slow link: the sparse
    //         uploads keep the server NIC idle, dense ones queue.
    println!("\n-- asynchronous server, 1GbE, heterogeneous fleet --");
    let mean_coords = (data.nnz() as f64 / data.n() as f64).max(1.0);
    for comp in [CompressorSpec::TopK { k: k0 }, CompressorSpec::Identity] {
        let rec = Experiment::new(LogisticModel::new(&data, lam))
            .dataset(&data.name)
            .method(MethodSpec::mem(comp))
            .schedule(Schedule::constant(0.5))
            .topology(Topology::ParamServerAsync {
                nodes: workers,
                net: NetworkModel::eth_1g(),
            })
            .compute(ComputeModel::new(1e-9, mean_coords))
            .hetero(0.5)
            .steps(rounds * workers)
            .eval_points(8)
            .seed(seed)
            .run()?;
        println!(
            "  {:<36} loss {:.4}  sim {:>8.3}s  staleness {:>5.1} (max {:>3})  link {:>5.1}%",
            rec.method,
            rec.final_loss(),
            rec.extra["sim_seconds"],
            rec.extra["mean_staleness"],
            rec.extra["max_staleness"],
            100.0 * rec.extra["link_utilization"],
        );
    }

    // ---- 3. Fault tolerance: checkpoint a sequential run mid-flight and
    //         resume bit-identically (what a preempted worker would do).
    println!("\n-- checkpoint / resume --");
    let d = data.d();
    let mut model = memsgd::models::LogisticModel::with_paper_lambda(&data);
    let mut opt = MemSgd::new(vec![0.0f32; d], compress::from_spec(&format!("top_k:{k0}"))?);
    let mut rng = Prng::new(seed);
    let mut grad = vec![0.0f32; d];
    use memsgd::models::GradBackend;
    for t in 0..500 {
        let i = rng.below(data.n());
        model.sample_grad(&opt.x, i, &mut grad);
        opt.step(&grad, 0.1, &mut rng);
        let _ = t;
    }
    let ck = Checkpoint::capture(&opt, &format!("top_k:{k0}"), &rng, None);
    let path = std::env::temp_dir().join("memsgd_distributed_example.ck");
    ck.save(&path)?;
    let (mut resumed, mut rng2, _) = Checkpoint::load(&path)?.restore()?;
    for _ in 500..1_000 {
        let i = rng2.below(data.n());
        model.sample_grad(&resumed.x, i, &mut grad);
        resumed.step(&grad, 0.1, &mut rng2);
    }
    println!(
        "  checkpointed at t=500 ({} bytes), resumed to t=1000, loss {:.4}",
        std::fs::metadata(&path)?.len(),
        model.full_loss(&resumed.x),
    );
    std::fs::remove_file(&path).ok();

    println!("\n{}", summary_table(&sync_records));
    Ok(())
}
