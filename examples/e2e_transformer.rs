//! End-to-end validation driver (mandated by DESIGN.md): train a
//! ~1M-parameter decoder-only transformer LM for a few hundred steps,
//! through **all three layers**:
//!
//!   L1 Pallas attention kernel (interpret=True) →
//!   L2 JAX fwd/bwd graph, AOT-lowered to HLO text →
//!   runtime: PJRT CPU executable loaded from artifacts/ →
//!   L3 Rust coordinator running Mem-SGD top-k on the flat gradient.
//!
//! Python does not run here — only the Rust binary and the PJRT plugin.
//! The loss curve on held-out Markov data is logged and written to
//! results/; EXPERIMENTS.md records a reference run.
//!
//! Run: `make artifacts && cargo run --release --example e2e_transformer --
//!       [--steps 300] [--k 1000] [--eta 0.15] [--compare-sgd]`

use std::time::Instant;

use memsgd::compress::from_spec;
use memsgd::metrics::{self, fmt_bits, LossPoint, RunRecord};
use memsgd::models::GradBackend;
use memsgd::optim::MemSgd;
use memsgd::runtime::pjrt::PjrtRuntime;
use memsgd::runtime::transformer::TransformerBackend;
use memsgd::util::cli::Args;
use memsgd::util::prng::Prng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let steps = args.get("steps", 300usize)?;
    let k = args.get("k", 1_000usize)?;
    let eta = args.get("eta", 0.15f64)?;
    let n_batches = args.get("batches", 24usize)?;
    let evals = args.get("evals", 12usize)?;
    let seed = args.get("seed", 1u64)?;
    let compare_sgd = args.flag("compare-sgd");
    args.finish()?;

    if !memsgd::runtime::artifacts_available() {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }
    let mut rt = PjrtRuntime::open_default()?;
    println!("PJRT platform: {}", rt.platform());
    let mut backend = TransformerBackend::new(&mut rt, n_batches, 3, seed)?;
    let meta = backend.rt.meta;
    println!(
        "model: {} params | vocab {} | {} layers × {} heads × d_model {} | seq {}\n\
         data: order-1 Markov corpus, {n_batches} train batches\n\
         method: Mem-SGD top-{k} on the flat gradient (compression ratio {:.0}x)\n",
        meta.param_count,
        meta.vocab,
        meta.n_layers,
        meta.n_heads,
        meta.d_model,
        meta.seq_len,
        meta.param_count as f64 / k as f64,
    );

    let mem_record = train_loop(&mut backend, steps, evals, eta, Some(k), seed)?;
    let mut records = vec![mem_record];

    if compare_sgd {
        println!("\n--- uncompressed SGD baseline (same schedule) ---");
        let sgd_record = train_loop(&mut backend, steps, evals, eta, None, seed)?;
        records.push(sgd_record);
        println!(
            "\nMem-SGD reached {:.4} vs SGD {:.4}, transmitting {} vs {}.",
            records[0].final_loss(),
            records[1].final_loss(),
            fmt_bits(records[0].total_bits),
            fmt_bits(records[1].total_bits),
        );
    }

    metrics::write_records("results/e2e_transformer.json", &records)?;
    println!("\nrecords -> results/e2e_transformer.json");
    Ok(())
}

/// Mem-SGD (Some(k)) or plain SGD (None) from the artifact's init params.
fn train_loop(
    backend: &mut TransformerBackend<'_>,
    steps: usize,
    evals: usize,
    eta: f64,
    top_k: Option<usize>,
    seed: u64,
) -> anyhow::Result<RunRecord> {
    let d = backend.dim();
    let n = backend.n();
    let mut rng = Prng::new(seed ^ 0xE2E);
    let comp_spec = match top_k {
        Some(k) => format!("top_k:{k}"),
        None => "identity".to_string(),
    };
    let mut opt = MemSgd::new(backend.initial_params(), from_spec(&comp_spec)?);
    let mut grad = vec![0.0f32; d];
    let eval_every = (steps / evals.max(1)).max(1);
    let mut record = RunRecord {
        method: format!("memsgd({comp_spec}) transformer"),
        dataset: "markov-lm".into(),
        schedule: format!("const(eta={eta})"),
        ..Default::default()
    };
    let started = Instant::now();
    let loss0 = backend.full_loss(&opt.x);
    record.curve.push(LossPoint { t: 0, bits: 0, loss: loss0 });
    println!("step {:>5}   held-out loss {loss0:.4}   (uniform = {:.4})", 0, (backend.rt.meta.vocab as f64).ln());
    for t in 0..steps {
        let i = rng.below(n);
        backend.sample_grad(&opt.x, i, &mut grad);
        opt.step(&grad, eta, &mut rng);
        if (t + 1) % eval_every == 0 || t + 1 == steps {
            let loss = backend.full_loss(&opt.x);
            record.curve.push(LossPoint { t: t + 1, bits: opt.bits_sent, loss });
            println!(
                "step {:>5}   held-out loss {loss:.4}   train {:.4}   sent {}   ({:.1}s)",
                t + 1,
                backend.last_train_loss,
                fmt_bits(opt.bits_sent),
                started.elapsed().as_secs_f64()
            );
        }
    }
    record.steps = steps;
    record.total_bits = opt.bits_sent;
    record.elapsed_ms = started.elapsed().as_secs_f64() * 1e3;
    Ok(record)
}
