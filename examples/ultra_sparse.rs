//! Ultra-sparsification (Remark 2.3): a `random_p` operator with
//! `k = p < 1` transmits **less than one coordinate per iteration on
//! average** — and Mem-SGD still converges, because the memory carries
//! everything that was not sent.
//!
//! Run: `cargo run --release --example ultra_sparse`

use anyhow::Result;

use memsgd::compress::CompressorSpec;
use memsgd::coordinator::{Experiment, MethodSpec};
use memsgd::experiments::{self, Which};
use memsgd::metrics::{fmt_bits, summary_table};
use memsgd::models::LogisticModel;
use memsgd::optim::Schedule;
use memsgd::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let scale = args.get("scale", 200usize)?;
    let steps = args.get("steps", 40_000usize)?;
    let seed = args.get("seed", 1u64)?;
    let data = experiments::dataset(Which::Epsilon, scale, seed);
    let n = data.n();
    let d = data.d();
    let lam = 1.0 / n as f64;

    println!(
        "== ultra-sparsification (Remark 2.3) on {} (n={n}, d={d}) ==\n",
        data.name
    );
    println!("operator random_p:p sends ONE coordinate with probability p,");
    println!("NOTHING otherwise — k = p < 1 in Definition 2.1.\n");

    let mut records = Vec::new();
    for p in [1.0, 0.5, 0.25, 0.1] {
        let k = p; // contraction parameter
        let shift = Schedule::paper_shift(d, k, 1.0);
        let rec = Experiment::new(LogisticModel::new(&data, lam))
            .dataset(&data.name)
            .method(MethodSpec::mem(CompressorSpec::RandomP { p }))
            .schedule(Schedule::inv_t(2.0, lam, shift))
            .steps(steps)
            .eval_points(12)
            .seed(seed ^ 0x07)
            .run()?;
        let bits_per_coord = 32.0 + (d as f64).log2().ceil(); // footnote-5 encoding
        let sent = rec
            .curve
            .last()
            .map(|pt| pt.bits as f64 / rec.steps as f64 / bits_per_coord)
            .unwrap_or(0.0);
        println!(
            "  p = {p:<5} final loss {:.4}   {:>9} total   {:.3} coords/iteration (expected {p})",
            rec.final_loss(),
            fmt_bits(rec.total_bits),
            sent,
        );
        records.push(rec);
    }

    // Vanilla baseline for the same budget.
    let sgd = Experiment::new(LogisticModel::new(&data, lam))
        .dataset(&data.name)
        .method(MethodSpec::Sgd)
        .schedule(Schedule::inv_t(2.0, lam, 1.0))
        .steps(steps)
        .eval_points(12)
        .seed(seed ^ 0x07)
        .run()?;
    println!(
        "  sgd       final loss {:.4}   {:>9} total   {d} coords/iteration",
        sgd.final_loss(),
        fmt_bits(sgd.total_bits),
    );
    records.push(sgd);

    println!("\n{}", summary_table(&records));
    println!("note: p<1 needs proportionally more iterations (T = Ω(d/k·√κ), Remark 2.6) —");
    println!("at this budget p=0.1 is visibly behind, exactly as the theory prices it.");
    Ok(())
}
