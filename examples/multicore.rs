//! Paper Section 4.4 — multicore shared-memory Mem-SGD (Figure 4).
//!
//! Two halves, mirroring DESIGN.md §3's substitution:
//!
//! 1. **Threaded Algorithm 2** on this machine: real `std::thread`
//!    workers, one shared lock-free parameter vector, private error
//!    memories — verifies convergence *under concurrency* for top-k,
//!    rand-k and the dense Hogwild-style baseline at a fixed total work
//!    budget.
//! 2. **Discrete-event speedup model** for the 24-core Xeon the paper
//!    used (this box has one core, so wall-clock speedup cannot be
//!    measured): regenerates Figure 4's speedup series from the cache-
//!    coherence mechanism.
//!
//! Run: `cargo run --release --example multicore -- [--dataset epsilon]
//!       [--workers 1,2,4,8,12,16,20,24] [--steps 40000]`

use memsgd::experiments::{self, Which};
use memsgd::metrics::summary_table;
use memsgd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let workers = args.get_list("workers", &[1usize, 2, 4, 8, 12, 16, 20, 24])?;
    let steps = args.get("steps", 40_000usize)?;
    let scale = args.get("scale", 100usize)?;
    let seed = args.get("seed", 1u64)?;
    args.finish()?;

    // ---- half 1: real threads, convergence under concurrency ----------
    let thread_workers: Vec<usize> = workers.iter().copied().filter(|&w| w <= 8).collect();
    println!(
        "threaded Algorithm 2 on {} — fixed budget {steps} total iterations,\n\
         final-iterate loss per (workers × compressor):\n",
        which.name()
    );
    let recs = experiments::figure4_threads(which, scale, steps, &thread_workers, seed)?;
    println!("{}", summary_table(&recs));

    // ---- half 2: simulated 24-core speedup -----------------------------
    println!("simulated speedup on the 24-core model (paper Figure 4):\n");
    let series = experiments::figure4_sim(which, &workers, seed);
    println!("{}", experiments::sim_table(&series));
    println!("lost (overwritten) updates at each worker count:");
    print!("{:<24}", "method");
    for &w in &workers {
        print!("{w:>8}");
    }
    println!();
    for s in &series {
        print!("{:<24}", s.method);
        for p in &s.points {
            print!("{:>8}", p.lost_updates);
        }
        println!();
    }
    println!(
        "\nreading: sparse Mem-SGD scales near-linearly to ~10 workers; the\n\
         dense lock-free baseline saturates on coherence traffic; top-k's\n\
         deterministic coordinate choice collides more than rand-k (the\n\
         paper's explanation for top-k ≈ rand-k in the parallel setting)."
    );
    Ok(())
}
