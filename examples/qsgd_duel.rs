//! Paper Section 4.3 — Mem-SGD vs QSGD (Figure 3), runnable.
//!
//! Same-iteration convergence *and* cumulative communicated bits for
//! Mem-SGD top-1 against QSGD at 2/4/8-bit quantization, under tuned
//! Bottou stepsizes, with the Appendix-B bit accounting (Elias estimate,
//! sparsity-aware effective dimension on RCV1).
//!
//! Run: `cargo run --release --example qsgd_duel -- [--dataset epsilon]
//!       [--scale 20] [--epochs 2] [--tune]`

use memsgd::experiments::{self, Which};
use memsgd::metrics::{self, fmt_bits, summary_table};
use memsgd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 20usize)?;
    let epochs = args.get("epochs", 2usize)?;
    let seed = args.get("seed", 1u64)?;
    // --tune runs the Appendix-B γ₀ grid search per method (slower);
    // the default uses γ₀ = 1.0, the winner on both surrogate datasets.
    let gamma0 = if args.flag("tune") { None } else { Some(1.0) };
    args.finish()?;

    println!("Figure 3 scenario on {} ({epochs} epochs, scale {scale})\n", which.name());
    let records = experiments::figure3(which, scale, epochs, 20, gamma0, seed)?;
    println!("{}", summary_table(&records));

    // The communication claim, made concrete: bits needed to reach the
    // best loss that every *improving* method reached. (Methods stuck at
    // the f(0) = ln 2 plateau — e.g. 2-bit QSGD under a short budget —
    // would otherwise set a target every curve trivially starts at.)
    let initial = records
        .first()
        .and_then(|r| r.curve.first())
        .map(|p| p.loss)
        .unwrap_or(f64::NAN);
    let common_target = records
        .iter()
        .map(|r| r.best_loss())
        .filter(|&b| b < initial - 1e-3)
        .fold(f64::MIN, f64::max)
        + 1e-4;
    println!("bits to reach loss {common_target:.4} (weakest improving method's best):");
    for r in &records {
        match r.bits_to(common_target) {
            Some(bits) => println!("  {:<24} {:>12}", r.method, fmt_bits(bits)),
            None => println!("  {:<24} {:>12}", r.method, "never"),
        }
    }

    metrics::write_records(
        format!("results/example_figure3_{}.json", which.name()),
        &records,
    )?;
    println!("\nrecords -> results/example_figure3_{}.json", which.name());
    Ok(())
}
