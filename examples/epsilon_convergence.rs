//! Paper Section 4.2 — "Verifying the Theory" (Figure 2), runnable.
//!
//! Reproduces the epsilon (or RCV1, `--dataset rcv1`) convergence panel:
//! Mem-SGD with top-k / rand-k for the paper's k grid under the
//! theoretical stepsizes of Table 2, against vanilla SGD and against the
//! "without delay" (a = 1) ablation, with quadratically-weighted iterate
//! averaging exactly as in Theorem 2.4.
//!
//! Run: `cargo run --release --example epsilon_convergence -- [--dataset epsilon]
//!       [--scale 20] [--epochs 2]`

use memsgd::experiments::{self, Which};
use memsgd::metrics::{self, summary_table};
use memsgd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let which = Which::parse(&args.get_str("dataset", "epsilon"))?;
    let scale = args.get("scale", 20usize)?;
    let epochs = args.get("epochs", 2usize)?;
    let seed = args.get("seed", 1u64)?;
    args.finish()?;

    println!(
        "Figure 2 scenario on {} (n = {}/{scale}, d = {}, {epochs} epochs)\n",
        which.name(),
        which.paper_n(),
        which.d()
    );
    let records = experiments::figure2(which, scale, epochs, 20, seed)?;
    println!("{}", summary_table(&records));

    // Loss-vs-iteration table, one column per method (plot-ready CSV).
    println!("loss curves (t, then one column per method):");
    print!("{:>8}", "t");
    for r in &records {
        print!(",{}", r.method.replace(' ', "_"));
    }
    println!();
    let npoints = records[0].curve.len();
    for p in 0..npoints {
        print!("{:>8}", records[0].curve[p].t);
        for r in &records {
            print!(",{:.6}", r.curve[p].loss);
        }
        println!();
    }

    metrics::write_records(
        format!("results/example_figure2_{}.json", which.name()),
        &records,
    )?;
    println!("\nrecords -> results/example_figure2_{}.json", which.name());
    Ok(())
}
