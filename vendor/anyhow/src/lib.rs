//! Minimal, dependency-free drop-in for the subset of the `anyhow` API
//! this workspace uses: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension
//! trait for `Result`/`Option`.
//!
//! Vendored so the workspace builds **offline** (the build container
//! has no crates.io access). Semantics follow the real crate where it
//! matters here:
//!
//! * `Error` carries a context chain; `{}` prints the outermost
//!   message, `{:#}` prints the whole chain separated by `": "`.
//! * `Error` deliberately does **not** implement `std::error::Error`,
//!   so the blanket `From<E: std::error::Error>` impl (what makes `?`
//!   work on `io::Error`, `ParseIntError`, ...) cannot conflict with
//!   the reflexive `From<Error> for Error`.

use std::fmt;

/// An error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (what [`Context::context`] does).
    pub fn push_context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and turn `None` into an error).
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_number(s: &str) -> Result<i32> {
        let n: i32 = s.parse().context("not a number")?;
        ensure!(n >= 0, "negative: {n}");
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_number("42").unwrap(), 42);
        let err = parse_number("x").unwrap_err();
        assert_eq!(format!("{err}"), "not a number");
        assert!(format!("{err:#}").starts_with("not a number: "));
    }

    #[test]
    fn ensure_and_bail_and_anyhow() {
        assert!(format!("{:#}", parse_number("-3").unwrap_err()).contains("negative: -3"));
        fn fails() -> Result<()> {
            bail!("boom {}", 7);
        }
        assert_eq!(fails().unwrap_err().to_string(), "boom 7");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let err = v.context("missing").unwrap_err();
        assert_eq!(err.to_string(), "missing");
    }

    #[test]
    fn with_context_chains() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::other("disk on fire"));
        let err = r.with_context(|| format!("writing {}", "f.json")).unwrap_err();
        assert_eq!(format!("{err:#}"), "writing f.json: disk on fire");
        assert_eq!(err.chain().count(), 2);
    }
}
