//! Compile-time **stub** of the `xla` (xla-rs) API surface the
//! `memsgd::runtime` module uses.
//!
//! The real crate links the native XLA/PJRT C++ library, which is not
//! present in the offline build container. This stub keeps the whole
//! workspace — including the PJRT runtime module, its callers, and the
//! e2e example — compiling; every entry point that would touch the
//! native library returns a descriptive [`Error`] instead. The
//! integration suite already skips PJRT tests when `artifacts/` is
//! absent, so the stub is never exercised there.
//!
//! To run the real artifact path, replace this path dependency in the
//! workspace `Cargo.toml` with the actual `xla` crate on a machine with
//! the XLA libraries installed; the API below matches the call sites.

use std::fmt;

/// Error raised by every stubbed operation.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "XLA runtime unavailable: {what} requires the native XLA/PJRT library \
         (this build uses the vendored stub; see vendor/xla)"
    )))
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a host slice (stub: shape-only).
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by an execution (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub (no native XLA)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_descriptive_errors() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        let msg = err.to_string();
        assert!(msg.contains("XLA runtime unavailable"), "{msg}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[1.0f32]).reshape(&[1]).is_err());
    }
}
