"""Layer-1 Pallas kernels: tiled logistic-regression margin + gradient.

This is the compute hot spot of the paper's workload (L2-regularized
logistic regression on epsilon / RCV1). Two kernels, both tiled over the
feature dimension so each grid step touches one VMEM-sized block of the
feature matrix:

* ``margin``:  z = X @ w, accumulated across feature tiles. On a real TPU
  each (B, Dt) x (Dt, 1) product runs on the MXU while the next X tile
  streams HBM -> VMEM; here the out BlockSpec maps every grid step to the
  same (B, 1) block, which is the canonical Pallas reduction pattern.
* ``grad``:    g_tile = X_tile^T @ coef / B + lam * w_tile, with the
  logistic coefficient coef = -y * sigmoid(-y * z) recomputed inside the
  kernel. Recomputing the (B, 1) elementwise chain per tile is ~B flops
  per grid step — far cheaper than materializing coef in HBM and reading
  it back, so the whole activation chain stays fused in VMEM.

Hardware adaptation (DESIGN.md §6): the paper targets multicore CPUs, so
there is no CUDA scheme to port; the adaptation is purely "express the
batched gradient as MXU matmuls with a BlockSpec-driven HBM<->VMEM
schedule". All kernels are built with ``interpret=True`` because the CPU
PJRT plugin cannot execute Mosaic custom-calls; interpret mode lowers to
plain HLO that the Rust runtime runs unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default feature-tile width. 256 f32 columns x a few hundred rows keeps a
# tile comfortably under typical VMEM budgets (B=256: 256*256*4 = 256 KiB
# per X tile) while staying a multiple of the 128-lane register width.
DEFAULT_BLOCK_D = 256


def _margin_kernel(x_ref, w_ref, o_ref):
    """One feature tile of z = X @ w. Grid: (num_d_tiles,)."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (B, Dt) @ (Dt, 1) -> (B, 1); accumulate across tiles.
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


def margin(x: jax.Array, w: jax.Array, *, block_d: int | None = None) -> jax.Array:
    """Tiled z = X @ w.

    Args:
        x: (B, D) features, D divisible by the tile width.
        w: (D, 1) weights.
        block_d: feature-tile width (default min(D, DEFAULT_BLOCK_D)).
    Returns:
        (B, 1) margins, same dtype as x.
    """
    b, d = x.shape
    bd = _pick_block(d, block_d)
    grid = (d // bd,)
    return pl.pallas_call(
        _margin_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, bd), lambda j: (0, j)),
            pl.BlockSpec((bd, 1), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b, 1), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), x.dtype),
        interpret=True,
    )(x, w)


def _grad_kernel(z_ref, y_ref, x_ref, w_ref, o_ref, *, lam: float, batch: int):
    """One feature tile of grad = X^T coef / B + lam w. Grid: (num_d_tiles,).

    The (B, 1) coefficient chain is recomputed per tile inside VMEM so the
    sigmoid never round-trips to HBM (see module docstring).
    """
    y = y_ref[...]
    coef = -y * jax.nn.sigmoid(-y * z_ref[...])  # (B, 1)
    xt_coef = jnp.dot(
        x_ref[...].T, coef, preferred_element_type=o_ref.dtype
    )  # (Dt, 1)
    o_ref[...] = xt_coef / batch + lam * w_ref[...]


def grad_from_margin(
    z: jax.Array,
    y: jax.Array,
    x: jax.Array,
    w: jax.Array,
    *,
    lam: float,
    block_d: int | None = None,
) -> jax.Array:
    """Tiled gradient given precomputed margins.

    Args:
        z: (B, 1) margins from :func:`margin`.
        y: (B, 1) labels in {-1, +1}.
        x: (B, D) features.
        w: (D, 1) weights.
        lam: L2 regularization strength.
    Returns:
        (D, 1) gradient of the mean regularized logistic loss.
    """
    b, d = x.shape
    bd = _pick_block(d, block_d)
    grid = (d // bd,)
    kernel = functools.partial(_grad_kernel, lam=lam, batch=b)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, 1), lambda j: (0, 0)),
            pl.BlockSpec((b, 1), lambda j: (0, 0)),
            pl.BlockSpec((b, bd), lambda j: (0, j)),
            pl.BlockSpec((bd, 1), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bd, 1), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((d, 1), x.dtype),
        interpret=True,
    )(z, y, x, w)


def logistic_grad(
    x: jax.Array,
    y: jax.Array,
    w: jax.Array,
    *,
    lam: float,
    block_d: int | None = None,
) -> jax.Array:
    """Full tiled logistic gradient: margin kernel then gradient kernel."""
    z = margin(x, w, block_d=block_d)
    return grad_from_margin(z, y, x, w, lam=lam, block_d=block_d)


def logistic_loss_and_grad(
    x: jax.Array,
    y: jax.Array,
    w: jax.Array,
    *,
    lam: float,
    block_d: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """(scalar mean loss, (D,1) gradient), sharing one margin pass.

    The loss reduction is a (B,) -> scalar logaddexp chain — negligible
    next to the matmuls — so it runs as plain jnp on the margins the
    Pallas kernel produced.
    """
    z = margin(x, w, block_d=block_d)
    g = grad_from_margin(z, y, x, w, lam=lam, block_d=block_d)
    loss = jnp.mean(jnp.logaddexp(0.0, -y * z)) + 0.5 * lam * jnp.sum(w * w)
    return loss, g


def _pick_block(d: int, block_d: int | None) -> int:
    """Choose a feature-tile width that divides d."""
    if block_d is not None:
        if d % block_d != 0:
            raise ValueError(f"block_d={block_d} must divide d={d}")
        return block_d
    bd = min(d, DEFAULT_BLOCK_D)
    while d % bd != 0:  # fall back to the largest divisor <= DEFAULT_BLOCK_D
        bd -= 1
    return bd
