"""Pure-jnp reference oracles for the Pallas kernels (Layer 1 correctness).

Every Pallas kernel in this package has a corresponding reference here,
written in straightforward jax.numpy with no tiling, no pallas, no tricks.
``python/tests/`` asserts ``allclose`` between kernel and oracle across a
hypothesis-driven sweep of shapes and dtypes; this is the core correctness
signal for Layer 1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def margin_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """z = X @ w.

    Args:
        x: (B, D) feature matrix.
        w: (D, 1) weight column.
    Returns:
        (B, 1) margins.
    """
    return x @ w


def logistic_coef_ref(z: jax.Array, y: jax.Array) -> jax.Array:
    """Per-example scalar factor of the logistic-loss gradient.

    For the loss l_i(w) = log(1 + exp(-y_i * z_i)) with z_i = x_i^T w,
    dl_i/dz_i = -y_i * sigmoid(-y_i * z_i). Shapes (B, 1) -> (B, 1).
    """
    return -y * jax.nn.sigmoid(-y * z)


def logistic_grad_ref(
    x: jax.Array, y: jax.Array, w: jax.Array, lam: float
) -> jax.Array:
    """Full-batch gradient of the L2-regularized logistic loss.

    grad = (1/B) X^T (-y * sigmoid(-y * Xw)) + lam * w

    Args:
        x: (B, D) features. y: (B, 1) labels in {-1, +1}. w: (D, 1) weights.
        lam: L2 regularization strength.
    Returns:
        (D, 1) gradient.
    """
    b = x.shape[0]
    z = margin_ref(x, w)
    coef = logistic_coef_ref(z, y)
    return x.T @ coef / b + lam * w


def logistic_loss_ref(
    x: jax.Array, y: jax.Array, w: jax.Array, lam: float
) -> jax.Array:
    """Mean L2-regularized logistic loss (scalar).

    loss = (1/B) sum_i log(1 + exp(-y_i x_i^T w)) + lam/2 ||w||^2
    Uses the numerically-stable log1p(exp(.)) = logaddexp(0, .) form.
    """
    z = margin_ref(x, w)
    per_example = jnp.logaddexp(0.0, -y * z)
    return jnp.mean(per_example) + 0.5 * lam * jnp.sum(w * w)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal single-head attention, the oracle for kernels.attention.

    Args:
        q, k, v: (S, Dh) per-head tensors.
    Returns:
        (S, Dh) attention output with causal masking.
    """
    s = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = (q @ k.T) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, jnp.asarray(-1e30, q.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    return probs @ v


def topk_compress_ref(v: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.topk.topk_compress: dense top-k split.

    Keeps every entry whose magnitude is >= the k-th largest magnitude
    (ties keep all), zeroes the rest; residual is the complement.

    Args:
        v: (D, 1) vector. k: sparsity, 1 <= k <= D.
    Returns:
        (g, r) with g + r == v exactly.
    """
    mags = jnp.abs(v[:, 0])
    tau = jax.lax.top_k(mags, k)[0][-1]
    keep = (jnp.abs(v) >= tau)
    g = jnp.where(keep, v, jnp.zeros_like(v))
    return g, v - g


def memsgd_step_ref(
    x: jax.Array, m: jax.Array, grad: jax.Array, eta: jax.Array, k: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for kernels.topk.memsgd_step (Algorithm 1, lines 4-6)."""
    v = m + eta.astype(x.dtype) * grad
    g, r = topk_compress_ref(v, k)
    return x - g, r, g
