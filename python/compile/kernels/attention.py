"""Layer-1 Pallas kernel: tiled causal attention for the e2e transformer.

The kernel tiles the query dimension: each grid step loads one (Sq, Dh)
query block plus the full (S, Dh) K and V panels into VMEM, computes the
masked scores on the MXU, softmaxes in-register, and writes one output
block. For the sequence lengths the e2e driver uses (S <= 256, Dh <= 64)
K and V fit VMEM whole, so the extra complexity of an online-softmax
inner loop over key tiles buys nothing; DESIGN.md §6 records the roofline
estimate.

Differentiability: ``pallas_call`` has no autodiff rule, so the public
:func:`attention` is a ``jax.custom_vjp`` whose forward runs the Pallas
kernel and whose backward is the VJP of the pure-jnp oracle
(:func:`compile.kernels.ref.attention_ref`). The two are asserted
allclose in python/tests, which makes the substitution exact up to float
associativity.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_Q = 64


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int):
    """One query tile of causal attention. Grid: (num_q_tiles,)."""
    i = pl.program_id(0)
    q = q_ref[...]  # (Bq, Dh)
    k = k_ref[...]  # (S, Dh)
    v = v_ref[...]  # (S, Dh)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # Causal mask: query row (global index i*Bq + r) attends keys <= itself.
    q_idx = i * block_q + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    k_idx = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(k_idx <= q_idx, scores, -1e30)
    # Numerically-stable softmax, fully in VMEM.
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p.astype(v.dtype), v, preferred_element_type=o_ref.dtype)


def _attention_fwd_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array, *, block_q: int
) -> jax.Array:
    s, dh = q.shape
    bq = min(block_q, s)
    while s % bq != 0:
        bq -= 1
    grid = (s // bq,)
    kernel = functools.partial(_attn_kernel, block_q=bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, dh), lambda i: (i, 0)),
            pl.BlockSpec((s, dh), lambda i: (0, 0)),
            pl.BlockSpec((s, dh), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, dh), q.dtype),
        interpret=True,
    )(q, k, v)


@jax.custom_vjp
def attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal single-head attention, (S, Dh) x3 -> (S, Dh)."""
    return _attention_fwd_pallas(q, k, v, block_q=DEFAULT_BLOCK_Q)


def _attention_fwd(q, k, v):
    return attention(q, k, v), (q, k, v)


def _attention_bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(ref.attention_ref, q, k, v)
    return vjp(g)


attention.defvjp(_attention_fwd, _attention_bwd)
