"""Layer-1 Pallas kernel for the paper's operator itself: fused
threshold-compress + error-residual (Algorithm 1, lines 4-6 without the
parameter update).

Top-k selection decomposes TPU-friendly into two phases:

1. **Threshold**: the magnitude of the k-th largest entry, computed at
   Layer 2 with ``jax.lax.top_k`` on |v| (an XLA-native sort-free
   reduction that lowers to efficient TPU code on its own — re-deriving
   a sorting network in Pallas would be slower and buy nothing).
2. **Mask + residual** (*the Pallas kernel*): one tiled pass emitting

       g = v * [|v| >= tau]          (the transmitted update)
       r = v - g                     (the new error memory content)

   fused so ``v`` streams HBM -> VMEM exactly once and both outputs are
   produced from registers. This is the memory-bandwidth-bound part —
   exactly the shape of fusion the hardware-adaptation note in
   DESIGN.md §6 calls for (the CPU-side analogue of this fusion is the
   §Perf story in EXPERIMENTS.md).

Tie semantics: entries with |v| exactly equal to the threshold are ALL
kept, so on ties the output may contain more than k nonzeros (still a
k-contraction — keeping more mass only shrinks the residual). The
hypothesis sweep in python/tests covers this.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .logistic_grad import _pick_block


def _mask_residual_kernel(v_ref, tau_ref, g_ref, r_ref):
    """One tile of g = v·[|v| ≥ τ], r = v − g. Grid: (num_tiles,)."""
    v = v_ref[...]
    tau = tau_ref[0, 0]
    keep = jnp.abs(v) >= tau
    g = jnp.where(keep, v, jnp.zeros_like(v))
    g_ref[...] = g
    r_ref[...] = v - g


def threshold_compress(
    v: jax.Array, tau: jax.Array, *, block_d: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """Fused (compressed, residual) split of a (D, 1) vector at |·| ≥ τ.

    Args:
        v: (D, 1) vector to compress (Algorithm 1's m + η∇f).
        tau: scalar magnitude threshold (0-d or (1,1) array).
        block_d: tile length (default: largest divisor of D ≤ 256).
    Returns:
        (g, r) with g + r == v, g the entries of magnitude ≥ τ, r the rest.
    """
    d = v.shape[0]
    bd = _pick_block(d, block_d)
    grid = (d // bd,)
    tau2 = jnp.reshape(tau.astype(v.dtype), (1, 1))
    return pl.pallas_call(
        _mask_residual_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bd, 1), lambda j: (j, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bd, 1), lambda j: (j, 0)),
            pl.BlockSpec((bd, 1), lambda j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, 1), v.dtype),
            jax.ShapeDtypeStruct((d, 1), v.dtype),
        ],
        interpret=True,
    )(v, tau2)


def topk_threshold(v: jax.Array, k: int) -> jax.Array:
    """Magnitude of the k-th largest |entry| of a (D, 1) vector (Layer 2).

    Implemented with ``jnp.sort`` rather than ``jax.lax.top_k``: the
    latter lowers to the modern ``topk(…, largest=true)`` HLO op which
    the runtime's bundled XLA (xla_extension 0.5.1) cannot parse from
    text, while ``sort`` round-trips fine (same constraint family as the
    HLO-text-vs-proto choice documented in aot.py).
    """
    mags = jnp.abs(v[:, 0])
    d = mags.shape[0]
    return jnp.sort(mags)[d - min(k, d)]


def topk_compress(
    v: jax.Array, k: int, *, block_d: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """Dense top-k split: (g, r) with g carrying the ≥-kth-magnitude
    entries (all of them on ties) and r the residual memory content."""
    tau = topk_threshold(v, k)
    return threshold_compress(v, tau, block_d=block_d)


def memsgd_step(
    x: jax.Array,
    m: jax.Array,
    grad: jax.Array,
    eta: jax.Array,
    *,
    k: int,
    block_d: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Algorithm 1, lines 4-6, entirely on-device.

    v = m + η·grad;  g = top_k(v);  x' = x − g;  m' = v − g.

    Args:
        x: (D, 1) iterate. m: (D, 1) error memory. grad: (D, 1) stochastic
        gradient. eta: scalar stepsize. k: sparsity.
    Returns:
        (x', m', g) — g dense-with-zeros (the wire format is Layer 3's
        concern; HLO artifacts have fixed shapes).
    """
    v = m + eta.astype(x.dtype) * grad
    g, r = topk_compress(v, k, block_d=block_d)
    return x - g, r, g


# Lowered by aot.py with static k baked into the artifact name.
def memsgd_step_entry(k: int):
    return functools.partial(memsgd_step, k=k)
