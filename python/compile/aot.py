"""AOT lowering: every Layer-2 entry point → ``artifacts/*.hlo.txt``.

This is the single build-time bridge between the Python authoring stack
(JAX + Pallas) and the Rust runtime. Each entry point is jitted, lowered
to StableHLO, converted to an XlaComputation, and dumped as **HLO text**.

HLO *text* — NOT ``lowered.compile().serialize()`` nor a serialized
``HloModuleProto`` — is the interchange format: jax >= 0.5 emits protos
with 64-bit instruction ids which the xla crate's bundled XLA
(xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``). The text parser
reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md and DESIGN.md §2.

Outputs (all under ``--outdir``, default ``../artifacts``):

* ``logreg_grad_b{B}_d{D}.hlo.txt``       — (w, x, y) → (grad,)
* ``logreg_loss_grad_b{B}_d{D}.hlo.txt``  — (w, x, y) → (loss, grad)
* ``logreg_loss_b{B}_d{D}.hlo.txt``       — (w, x, y) → (loss,)
* ``memsgd_step_k{K}_d{D}.hlo.txt``       — (x, m, grad, eta) → (x', m', g)
                                            Algorithm 1 lines 4-6 on-device
* ``transformer_step.hlo.txt``            — (flat_params, tokens) → (loss, flat_grad)
* ``transformer_loss.hlo.txt``            — (flat_params, tokens) → (loss,)
* ``transformer_init.bin``                — flat f32 LE initial parameters
* ``manifest.json``                       — machine-readable artifact index

Regularization note: the logistic artifacts are lowered with ``lam=0.0``
(pure data term). The Rust coordinator adds ``lam * w`` / ``0.5*lam*|w|^2``
itself, which keeps one artifact valid for every regularizer strength
instead of baking a dataset-specific constant into the HLO.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Logistic-regression shapes exported by default. (B, D) pairs:
#   256 x 2000 — paper's epsilon dataset width, production batch.
#   64  x 512  — small shape for integration tests and the quickstart.
LOGREG_SHAPES: tuple[tuple[int, int], ...] = ((256, 2000), (64, 512))

# Mem-SGD on-device step shapes exported by default: (D, k).
MEMSGD_STEP_SHAPES: tuple[tuple[int, int], ...] = ((512, 8),)

TRANSFORMER_CFG = model.TransformerConfig()


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the only safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape: Sequence[int], dtype: Any) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dtype_name(dt: Any) -> str:
    return {"float32": "f32", "int32": "i32", "float64": "f64", "int64": "i64"}[
        jnp.dtype(dt).name
    ]


def _io_entry(args: Sequence[jax.ShapeDtypeStruct], outs: Sequence[jax.ShapeDtypeStruct]):
    return (
        [{"dims": list(a.shape), "dtype": _dtype_name(a.dtype)} for a in args],
        [{"dims": list(o.shape), "dtype": _dtype_name(o.dtype)} for o in outs],
    )


def lower_entry(fn, args: Sequence[jax.ShapeDtypeStruct], outdir: str, name: str) -> dict:
    """Lower ``fn`` at ``args`` and write ``{name}.hlo.txt``; return manifest row."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(outdir, fname), "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *args)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    inputs, outputs = _io_entry(args, outs)
    print(f"  {fname}: {len(text)} chars, {len(inputs)} inputs -> {len(outputs)} outputs")
    return {"name": name, "file": fname, "inputs": inputs, "outputs": outputs}


def export_logreg(outdir: str) -> list[dict]:
    rows: list[dict] = []
    for b, d in LOGREG_SHAPES:
        w = _spec((d, 1), jnp.float32)
        x = _spec((b, d), jnp.float32)
        y = _spec((b, 1), jnp.float32)
        rows.append(
            lower_entry(
                lambda w, x, y: model.logistic_grad(w, x, y, lam=0.0),
                (w, x, y), outdir, f"logreg_grad_b{b}_d{d}",
            )
        )
        rows.append(
            lower_entry(
                lambda w, x, y: model.logistic_loss_grad(w, x, y, lam=0.0),
                (w, x, y), outdir, f"logreg_loss_grad_b{b}_d{d}",
            )
        )
        rows.append(
            lower_entry(
                lambda w, x, y: model.logistic_loss(w, x, y, lam=0.0),
                (w, x, y), outdir, f"logreg_loss_b{b}_d{d}",
            )
        )
        for row in rows[-3:]:
            row["meta"] = {"batch": b, "dim": d, "reg_applied": False}
    return rows


def export_memsgd_step(outdir: str) -> list[dict]:
    """Algorithm 1 lines 4-6 on-device (kernels/topk.py): one artifact per
    (d, k). The Rust runtime cross-checks these against the native
    MemSgd::step (rust/tests/integration_runtime.rs)."""
    from .kernels import topk

    rows: list[dict] = []
    for d, k in MEMSGD_STEP_SHAPES:
        vec = _spec((d, 1), jnp.float32)
        eta = _spec((), jnp.float32)
        rows.append(
            lower_entry(
                topk.memsgd_step_entry(k),
                (vec, vec, vec, eta),
                outdir,
                f"memsgd_step_k{k}_d{d}",
            )
        )
        rows[-1]["meta"] = {"dim": d, "k": k}
    return rows


def export_transformer(outdir: str) -> list[dict]:
    cfg = TRANSFORMER_CFG
    step, flat0, _ = model.make_transformer_step(cfg)
    loss_fn = model.make_lm_loss_fn(cfg)
    p = int(flat0.shape[0])
    params = _spec((p,), jnp.float32)
    tokens = _spec((cfg.batch, cfg.seq_len + 1), jnp.int32)

    rows = [
        lower_entry(step, (params, tokens), outdir, "transformer_step"),
        lower_entry(loss_fn, (params, tokens), outdir, "transformer_loss"),
    ]
    init_file = "transformer_init.bin"
    with open(os.path.join(outdir, init_file), "wb") as f:
        import numpy as np

        f.write(np.asarray(flat0, dtype="<f4").tobytes())
    meta = {
        "param_count": p,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers,
        "d_ff": cfg.d_ff,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "init_file": init_file,
    }
    for row in rows:
        row["meta"] = meta
    print(f"  {init_file}: {p} f32 params")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--only",
        choices=["logreg", "memsgd", "transformer", "all"],
        default="all",
        help="subset to export (the transformer lowering is the slow part)",
    )
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    outdir = os.path.dirname(args.out) if args.out else args.outdir
    os.makedirs(outdir, exist_ok=True)

    rows: list[dict] = []
    if args.only in ("logreg", "all"):
        print("exporting logistic-regression artifacts:")
        rows += export_logreg(outdir)
    if args.only in ("memsgd", "all"):
        print("exporting memsgd on-device step artifacts:")
        rows += export_memsgd_step(outdir)
    if args.only in ("transformer", "all"):
        print("exporting transformer artifacts:")
        rows += export_transformer(outdir)

    manifest = {"format": 1, "entries": rows}
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(outdir, 'manifest.json')} ({len(rows)} entries)")


if __name__ == "__main__":
    main()
