"""Layer-2 JAX models: the compute graphs the Rust coordinator executes.

Two model families, both calling the Layer-1 Pallas kernels
(:mod:`compile.kernels`), both lowered once by :mod:`compile.aot` to HLO
text and executed from Rust via PJRT. Python never runs at training time.

1. **Logistic regression** — the paper's workload (Section 4): the batched
   L2-regularized logistic gradient, with the matmul hot spots expressed as
   the tiled Pallas kernels in ``kernels/logistic_grad.py``.

2. **Decoder-only transformer LM** — the end-to-end validation workload
   mandated by the brief: a ~1M-parameter causal LM whose per-head
   attention is the Pallas kernel in ``kernels/attention.py``. The exported
   entry point takes a *flat* f32 parameter vector and a token batch and
   returns ``(mean loss, flat gradient)`` so the Rust side can treat the
   model as an opaque ``R^P -> (R, R^P)`` oracle and run Mem-SGD on the
   flat gradient exactly as it does for logistic regression.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels import attention as attention_kernel
from .kernels import logistic_grad as logistic_kernel

# ---------------------------------------------------------------------------
# Logistic regression (paper Section 4 workload)
# ---------------------------------------------------------------------------


def logistic_grad(w: jax.Array, x: jax.Array, y: jax.Array, *, lam: float) -> tuple[jax.Array]:
    """Batched gradient of the mean L2-regularized logistic loss.

    Args:
        w: (D, 1) weights.
        x: (B, D) features.
        y: (B, 1) labels in {-1, +1}.
        lam: L2 regularization strength (baked into the artifact).
    Returns:
        1-tuple of the (D, 1) gradient (AOT lowers with return_tuple=True).
    """
    return (logistic_kernel.logistic_grad(x, y, w, lam=lam),)


def logistic_loss_grad(
    w: jax.Array, x: jax.Array, y: jax.Array, *, lam: float
) -> tuple[jax.Array, jax.Array]:
    """(scalar mean loss, (D,1) gradient), sharing one Pallas margin pass."""
    loss, g = logistic_kernel.logistic_loss_and_grad(x, y, w, lam=lam)
    return loss, g


def logistic_loss(w: jax.Array, x: jax.Array, y: jax.Array, *, lam: float) -> tuple[jax.Array]:
    """Scalar mean loss only — used by the Rust loss-evaluation schedule."""
    z = logistic_kernel.margin(x, w)
    per_example = jnp.logaddexp(0.0, -y * z)
    return (jnp.mean(per_example) + 0.5 * lam * jnp.sum(w * w),)


# ---------------------------------------------------------------------------
# Decoder-only transformer LM (end-to-end validation workload)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    """Static architecture of the e2e LM (baked into the HLO artifact)."""

    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    seq_len: int = 64
    batch: int = 8

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def init_params(cfg: TransformerConfig, key: jax.Array) -> dict[str, Any]:
    """Initialize the LM parameter pytree (scaled-normal init)."""
    keys = jax.random.split(key, 4 + cfg.n_layers)
    scale = 0.02
    params: dict[str, Any] = {
        "embed": scale * jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)),
        "pos": scale * jax.random.normal(keys[1], (cfg.seq_len, cfg.d_model)),
        "unembed": scale * jax.random.normal(keys[2], (cfg.d_model, cfg.vocab)),
        "ln_f": {"g": jnp.ones((cfg.d_model,)), "b": jnp.zeros((cfg.d_model,))},
        "layers": [],
    }
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[4 + i], 6)
        params["layers"].append(
            {
                "wq": scale * jax.random.normal(lk[0], (cfg.d_model, cfg.d_model)),
                "wk": scale * jax.random.normal(lk[1], (cfg.d_model, cfg.d_model)),
                "wv": scale * jax.random.normal(lk[2], (cfg.d_model, cfg.d_model)),
                "wo": scale * jax.random.normal(lk[3], (cfg.d_model, cfg.d_model)),
                "w1": scale * jax.random.normal(lk[4], (cfg.d_model, cfg.d_ff)),
                "w2": scale * jax.random.normal(lk[5], (cfg.d_ff, cfg.d_model)),
                "ln1": {"g": jnp.ones((cfg.d_model,)), "b": jnp.zeros((cfg.d_model,))},
                "ln2": {"g": jnp.ones((cfg.d_model,)), "b": jnp.zeros((cfg.d_model,))},
            }
        )
    return params


def param_count(cfg: TransformerConfig) -> int:
    """Number of scalar parameters for ``cfg``."""
    flat, _ = ravel_pytree(init_params(cfg, jax.random.PRNGKey(0)))
    return int(flat.shape[0])


def _layer_norm(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return g * (x - mu) * jax.lax.rsqrt(var + 1e-5) + b


def _multi_head_attention(x: jax.Array, layer: dict[str, Any], cfg: TransformerConfig) -> jax.Array:
    """Causal MHA over (B, S, D) activations via the Pallas per-head kernel."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    q = (x @ layer["wq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    k = (x @ layer["wk"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    v = (x @ layer["wv"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    o = jax.vmap(attention_kernel.attention)(q, k, v)  # (B*H, S, Dh)
    o = o.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    return o @ layer["wo"]


def _block(x: jax.Array, layer: dict[str, Any], cfg: TransformerConfig) -> jax.Array:
    h = _layer_norm(x, layer["ln1"]["g"], layer["ln1"]["b"])
    x = x + _multi_head_attention(h, layer, cfg)
    h = _layer_norm(x, layer["ln2"]["g"], layer["ln2"]["b"])
    x = x + jax.nn.gelu(h @ layer["w1"]) @ layer["w2"]
    return x


def lm_loss(params: dict[str, Any], tokens: jax.Array, cfg: TransformerConfig) -> jax.Array:
    """Mean next-token cross-entropy of a (B, S+1) token batch."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    x = params["embed"][inputs] + params["pos"][None, :, :]
    for layer in params["layers"]:
        x = _block(x, layer, cfg)
    x = _layer_norm(x, params["ln_f"]["g"], params["ln_f"]["b"])
    logits = x @ params["unembed"]  # (B, S, V)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def make_transformer_step(cfg: TransformerConfig):
    """Build the exported ``flat params, tokens -> (loss, flat grad)`` fn.

    Returns:
        (step_fn, flat_init, unravel) where ``flat_init`` is the flat f32
        initial parameter vector the Rust coordinator starts from.
    """
    params0 = init_params(cfg, jax.random.PRNGKey(0))
    flat0, unravel = ravel_pytree(params0)
    flat0 = flat0.astype(jnp.float32)

    def step(flat_params: jax.Array, tokens: jax.Array) -> tuple[jax.Array, jax.Array]:
        params = unravel(flat_params)
        loss, grads = jax.value_and_grad(lm_loss)(params, tokens, cfg)
        flat_grad, _ = ravel_pytree(grads)
        return loss, flat_grad.astype(jnp.float32)

    return step, flat0, unravel


def make_lm_loss_fn(cfg: TransformerConfig):
    """Loss-only flat entry point (Rust evaluation schedule)."""
    params0 = init_params(cfg, jax.random.PRNGKey(0))
    _, unravel = ravel_pytree(params0)

    def loss_fn(flat_params: jax.Array, tokens: jax.Array) -> tuple[jax.Array]:
        return (lm_loss(unravel(flat_params), tokens, cfg),)

    return loss_fn


@functools.lru_cache(maxsize=4)
def transformer_step_jit(cfg: TransformerConfig):
    """Jitted step for the python test-suite (cached per config)."""
    return jax.jit(make_transformer_step(cfg)[0])
