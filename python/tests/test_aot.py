"""AOT bridge: the HLO text artifacts and manifest must be loadable and
numerically equal to the jitted Python graphs (via the CPU PJRT client
from the *python* side; the Rust side re-checks the same numbers in
rust/tests/integration_runtime.rs).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
def test_manifest_schema():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == 1
    names = {e["name"] for e in manifest["entries"]}
    assert "transformer_step" in names
    assert any(n.startswith("logreg_grad_") for n in names)
    for e in manifest["entries"]:
        assert os.path.exists(os.path.join(ARTIFACTS, e["file"])), e["file"]
        for io in e["inputs"] + e["outputs"]:
            assert io["dtype"] in ("f32", "i32")
            assert all(isinstance(d, int) for d in io["dims"])


@needs_artifacts
def test_transformer_init_bin_matches_manifest():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    entry = next(e for e in manifest["entries"] if e["name"] == "transformer_step")
    p = entry["meta"]["param_count"]
    raw = np.fromfile(os.path.join(ARTIFACTS, entry["meta"]["init_file"]), dtype="<f4")
    assert raw.shape == (p,)
    assert np.all(np.isfinite(raw))
    # Must match the deterministic PRNGKey(0) init.
    _, flat0, _ = model.make_transformer_step(model.TransformerConfig(**{
        k: entry["meta"][k]
        for k in ("vocab", "d_model", "n_heads", "n_layers", "d_ff", "seq_len", "batch")
    }))
    np.testing.assert_allclose(raw, np.asarray(flat0), rtol=0, atol=0)


def test_hlo_text_parses_back():
    """The emitted HLO text must parse back into an HloModule, and the
    jitted graph it was lowered from must match the reference oracle.

    (Executing the parsed text end-to-end is the Rust runtime's job —
    rust/tests/integration_runtime.rs compiles the same artifacts through
    PJRT and re-checks these numbers.)
    """
    b, d = 16, 64
    w = jax.ShapeDtypeStruct((d, 1), jnp.float32)
    x = jax.ShapeDtypeStruct((b, d), jnp.float32)
    y = jax.ShapeDtypeStruct((b, 1), jnp.float32)
    fn = jax.jit(lambda w, x, y: model.logistic_grad(w, x, y, lam=0.0))
    text = aot.to_hlo_text(fn.lower(w, x, y))
    assert "ENTRY" in text and "f32[" in text

    from jax._src.lib import xla_client as xc

    module = xc._xla.hlo_module_from_text(text)  # raises on malformed text
    assert "f32[64,1]" in module.to_string()

    rng = np.random.default_rng(0)
    wv = rng.normal(size=(d, 1)).astype(np.float32) * 0.1
    xv = rng.normal(size=(b, d)).astype(np.float32)
    yv = np.sign(rng.normal(size=(b, 1))).astype(np.float32)
    (got,) = fn(jnp.asarray(wv), jnp.asarray(xv), jnp.asarray(yv))
    want = ref.logistic_grad_ref(jnp.asarray(xv), jnp.asarray(yv), jnp.asarray(wv), 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@needs_artifacts
def test_exported_logreg_artifact_text_is_valid_hlo():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        manifest = json.load(f)
    for e in manifest["entries"]:
        if not e["file"].endswith(".hlo.txt"):
            continue
        with open(os.path.join(ARTIFACTS, e["file"])) as f:
            text = f.read()
        assert "ENTRY" in text, e["file"]
        # return_tuple=True → root instruction is a tuple.
        assert "tuple(" in text or "tuple " in text, e["file"]
