"""Layer-1 correctness for kernels/topk.py: the fused threshold-compress
+ residual kernel and the on-device Mem-SGD step, against the pure-jnp
oracles in kernels/ref.py (hypothesis sweep over shapes/k/seeds)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, topk


def _vec(d, seed, scale=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(d, 1)) * scale, jnp.float32)


@settings(deadline=None, max_examples=25)
@given(
    d=st.sampled_from([8, 64, 256, 512, 1000]),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_topk_compress_matches_ref(d, k, seed):
    v = _vec(d, seed)
    g, r = topk.topk_compress(v, min(k, d))
    g_ref, r_ref = ref.topk_compress_ref(v, min(k, d))
    np.testing.assert_allclose(g, g_ref, rtol=0, atol=0)
    np.testing.assert_allclose(r, r_ref, rtol=0, atol=0)


@settings(deadline=None, max_examples=20)
@given(
    d=st.sampled_from([64, 512]),
    k=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_split_is_exact_partition(d, k, seed):
    v = _vec(d, seed)
    g, r = topk.topk_compress(v, k)
    # g + r reconstructs v exactly (bitwise: the kernel moves, never adds).
    np.testing.assert_array_equal(np.asarray(g) + np.asarray(r), np.asarray(v))
    # Disjoint supports.
    assert not np.any((np.asarray(g) != 0) & (np.asarray(r) != 0))
    # At least k nonzeros in g (== k when magnitudes are distinct).
    assert int(np.count_nonzero(np.asarray(g))) >= min(k, d)


def test_contraction_property():
    # Definition 2.1, pointwise for top-k: ‖r‖² ≤ (1 − k/d)‖v‖².
    for seed in range(5):
        d, k = 512, 4
        v = _vec(d, seed)
        _, r = topk.topk_compress(v, k)
        lhs = float(jnp.sum(r * r))
        rhs = (1 - k / d) * float(jnp.sum(v * v))
        assert lhs <= rhs + 1e-6


def test_tie_keeps_all_equal_magnitudes():
    v = jnp.asarray([[2.0], [-2.0], [1.0], [0.5]], jnp.float32)
    g, r = topk.topk_compress(v, 1)
    # |2.0| and |-2.0| tie at the threshold: both kept.
    np.testing.assert_array_equal(np.asarray(g)[:, 0], [2.0, -2.0, 0.0, 0.0])
    np.testing.assert_array_equal(np.asarray(r)[:, 0], [0.0, 0.0, 1.0, 0.5])


def test_threshold_zero_keeps_everything():
    v = _vec(64, 7)
    g, r = topk.threshold_compress(v, jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(g), np.asarray(v))
    np.testing.assert_array_equal(np.asarray(r), np.zeros_like(v))


@settings(deadline=None, max_examples=15)
@given(
    d=st.sampled_from([64, 512]),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_memsgd_step_matches_ref(d, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(d, 1)), jnp.float32)
    m = jnp.asarray(rng.normal(size=(d, 1)) * 0.1, jnp.float32)
    grad = jnp.asarray(rng.normal(size=(d, 1)), jnp.float32)
    eta = jnp.float32(abs(rng.normal()) * 0.1 + 1e-3)
    out = topk.memsgd_step(x, m, grad, eta, k=k)
    want = ref.memsgd_step_ref(x, m, grad, eta, k)
    for a, b in zip(out, want):
        np.testing.assert_allclose(a, b, rtol=0, atol=0)


def test_memsgd_step_conservation():
    # x' + m' == x + m + η·grad − ... : the invariant x − x̃ = m (eq. 12)
    # in one step: (x − g) + (v − g) + g... direct identity:
    # x' + m' = x + m + η·grad − g. Check g + (x' − x) == 0 and m' == v − g.
    d, k = 512, 8
    x, m, grad = _vec(d, 1), _vec(d, 2, 0.1), _vec(d, 3)
    eta = jnp.float32(0.05)
    x2, m2, g = topk.memsgd_step(x, m, grad, eta, k=k)
    v = m + eta * grad
    np.testing.assert_allclose(np.asarray(x2), np.asarray(x - g), atol=0)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(v - g), atol=0)
    # Conservation: everything not transmitted stays in memory.
    np.testing.assert_allclose(
        np.asarray(g + m2), np.asarray(v), rtol=0, atol=1e-7
    )


def test_memsgd_step_lowers_to_hlo_text():
    # The artifact path must survive the jit→stablehlo→HLO-text round trip.
    from compile import aot

    fn = topk.memsgd_step_entry(8)
    vec = jax.ShapeDtypeStruct((512, 1), jnp.float32)
    eta = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(fn).lower(vec, vec, vec, eta)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and len(text) > 500


def test_block_must_divide():
    v = _vec(100, 1)
    with pytest.raises(ValueError):
        topk.threshold_compress(v, jnp.float32(1.0), block_d=33)
