"""Layer-2 correctness: model graphs, shapes, and training signal."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

SMALL_CFG = model.TransformerConfig(
    vocab=64, d_model=32, n_heads=2, n_layers=2, d_ff=64, seq_len=16, batch=4
)


# ---------------------------------------------------------------------------
# logistic entry points
# ---------------------------------------------------------------------------


def _case(b=32, d=100, seed=0, lam=0.01):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k0, (b, d), jnp.float32)
    w = 0.1 * jax.random.normal(k1, (d, 1), jnp.float32)
    y = jnp.where(jax.random.bernoulli(k2, 0.5, (b, 1)), 1.0, -1.0).astype(jnp.float32)
    return w, x, y, lam


def test_logistic_grad_entry_matches_ref():
    w, x, y, lam = _case()
    (g,) = model.logistic_grad(w, x, y, lam=lam)
    np.testing.assert_allclose(g, ref.logistic_grad_ref(x, y, w, lam), rtol=1e-4, atol=1e-5)


def test_logistic_loss_grad_entry_consistent():
    w, x, y, lam = _case(seed=5)
    loss, g = model.logistic_loss_grad(w, x, y, lam=lam)
    (loss_only,) = model.logistic_loss(w, x, y, lam=lam)
    np.testing.assert_allclose(loss, loss_only, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(g, ref.logistic_grad_ref(x, y, w, lam), rtol=1e-4, atol=1e-5)


def test_logreg_zero_lam_artifact_contract():
    """Artifacts are lowered with lam=0; Rust adds lam*w. Verify the split."""
    w, x, y, _ = _case(seed=9)
    lam = 0.37
    (g0,) = model.logistic_grad(w, x, y, lam=0.0)
    (g,) = model.logistic_grad(w, x, y, lam=lam)
    np.testing.assert_allclose(g0 + lam * w, g, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# transformer
# ---------------------------------------------------------------------------


def test_param_count_formula():
    cfg = SMALL_CFG
    per_layer = 4 * cfg.d_model**2 + 2 * cfg.d_model * cfg.d_ff + 4 * cfg.d_model
    expected = (
        cfg.vocab * cfg.d_model  # embed
        + cfg.seq_len * cfg.d_model  # pos
        + cfg.d_model * cfg.vocab  # unembed
        + 2 * cfg.d_model  # final ln
        + cfg.n_layers * per_layer
    )
    assert model.param_count(cfg) == expected


def test_default_config_is_about_1m_params():
    p = model.param_count(model.TransformerConfig())
    assert 0.5e6 < p < 2e6, p


def test_step_shapes_and_finite():
    cfg = SMALL_CFG
    step, flat0, _ = model.make_transformer_step(cfg)
    tokens = jax.random.randint(
        jax.random.PRNGKey(0), (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab, jnp.int32
    )
    loss, grad = step(flat0, tokens)
    assert loss.shape == ()
    assert grad.shape == flat0.shape
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.all(jnp.isfinite(grad)))
    # Initial loss of a near-uniform model ~ log(vocab).
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


def test_step_gradient_descends():
    """A few plain-SGD steps on one batch must reduce the loss."""
    cfg = SMALL_CFG
    step = jax.jit(model.make_transformer_step(cfg)[0])
    _, flat0, _ = model.make_transformer_step(cfg)
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab, jnp.int32
    )
    w = flat0
    losses = []
    for _ in range(10):
        loss, g = step(w, tokens)
        losses.append(float(loss))
        w = w - 0.5 * g
    assert losses[-1] < losses[0] - 0.05, losses


def test_loss_fn_matches_step_loss():
    cfg = SMALL_CFG
    step, flat0, _ = model.make_transformer_step(cfg)
    loss_fn = model.make_lm_loss_fn(cfg)
    tokens = jax.random.randint(
        jax.random.PRNGKey(2), (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab, jnp.int32
    )
    l1, _ = step(flat0, tokens)
    (l2,) = loss_fn(flat0, tokens)
    np.testing.assert_allclose(l1, l2, rtol=1e-6, atol=1e-7)


def test_grad_matches_finite_difference():
    cfg = model.TransformerConfig(
        vocab=16, d_model=8, n_heads=2, n_layers=1, d_ff=16, seq_len=8, batch=2
    )
    step, flat0, _ = model.make_transformer_step(cfg)
    loss_fn = model.make_lm_loss_fn(cfg)
    tokens = jax.random.randint(
        jax.random.PRNGKey(3), (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab, jnp.int32
    )
    _, g = step(flat0, tokens)
    # Directional finite difference along a random unit vector.
    u = jax.random.normal(jax.random.PRNGKey(4), flat0.shape)
    u = u / jnp.linalg.norm(u)
    eps = 1e-3
    (lp,) = loss_fn(flat0 + eps * u, tokens)
    (lm,) = loss_fn(flat0 - eps * u, tokens)
    fd = (float(lp) - float(lm)) / (2 * eps)
    analytic = float(jnp.dot(g, u))
    assert abs(fd - analytic) < 5e-3 * max(1.0, abs(fd)), (fd, analytic)


def test_causality_of_lm_loss():
    """Changing a future *target-only* token must not change earlier logits.

    We test via the step loss: perturbing token S (the last target) changes
    the loss, but perturbing it must not change gradients w.r.t. positions
    that cannot see it... cheaper proxy: loss changes, finite, no NaN.
    """
    cfg = SMALL_CFG
    loss_fn = model.make_lm_loss_fn(cfg)
    _, flat0, _ = model.make_transformer_step(cfg)
    tokens = jax.random.randint(
        jax.random.PRNGKey(5), (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab, jnp.int32
    )
    (l1,) = loss_fn(flat0, tokens)
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % cfg.vocab)
    (l2,) = loss_fn(flat0, tokens2)
    assert bool(jnp.isfinite(l1)) and bool(jnp.isfinite(l2))
    assert float(jnp.abs(l1 - l2)) > 1e-6
