"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

This is the core correctness signal for the kernel layer: hypothesis
sweeps shapes / dtypes / regularizer strengths and asserts allclose
between ``compile.kernels.*`` (tiled Pallas, interpret=True) and
``compile.kernels.ref`` (straight jnp).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attention_kernel
from compile.kernels import logistic_grad as lk
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=25, deadline=None)


def _logreg_case(b: int, d: int, seed: int, dtype=jnp.float32):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k0, (b, d), dtype)
    w = 0.1 * jax.random.normal(k1, (d, 1), dtype)
    y = jnp.sign(jax.random.normal(k2, (b, 1), dtype))
    y = jnp.where(y == 0, 1.0, y).astype(dtype)
    return x, y, w


# ---------------------------------------------------------------------------
# margin kernel
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(min_value=1, max_value=96),
    d=st.integers(min_value=1, max_value=640),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_margin_matches_ref(b, d, seed):
    x, _, w = _logreg_case(b, d, seed)
    got = lk.margin(x, w)
    want = ref.margin_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_d", [1, 2, 8, 64, 250, 256])
def test_margin_explicit_blocks(block_d):
    d = 1000 if 1000 % block_d == 0 else block_d * 4
    x, _, w = _logreg_case(32, d, 7)
    got = lk.margin(x, w, block_d=block_d)
    np.testing.assert_allclose(got, ref.margin_ref(x, w), rtol=1e-5, atol=1e-5)


def test_margin_rejects_nondividing_block():
    x, _, w = _logreg_case(4, 10, 0)
    with pytest.raises(ValueError):
        lk.margin(x, w, block_d=3)


# ---------------------------------------------------------------------------
# gradient kernel
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(min_value=1, max_value=96),
    d=st.integers(min_value=1, max_value=640),
    lam=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_logistic_grad_matches_ref(b, d, lam, seed):
    x, y, w = _logreg_case(b, d, seed)
    got = lk.logistic_grad(x, y, w, lam=lam)
    want = ref.logistic_grad_ref(x, y, w, lam)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(
    b=st.integers(min_value=1, max_value=64),
    d=st.integers(min_value=1, max_value=512),
    lam=st.floats(min_value=0.0, max_value=0.5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_loss_and_grad_matches_ref(b, d, lam, seed):
    x, y, w = _logreg_case(b, d, seed)
    loss, g = lk.logistic_loss_and_grad(x, y, w, lam=lam)
    np.testing.assert_allclose(loss, ref.logistic_loss_ref(x, y, w, lam), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(g, ref.logistic_grad_ref(x, y, w, lam), rtol=1e-4, atol=1e-5)


def test_grad_agrees_with_jax_autodiff():
    """The kernel must match d/dw of the reference loss, not just ref.py."""
    x, y, w = _logreg_case(48, 300, 3)
    lam = 0.01
    auto = jax.grad(lambda w: ref.logistic_loss_ref(x, y, w, lam))(w)
    got = lk.logistic_grad(x, y, w, lam=lam)
    np.testing.assert_allclose(got, auto, rtol=1e-4, atol=1e-5)


def test_grad_large_margins_stable():
    """Saturated sigmoids (|z| large) must not produce NaN/Inf."""
    x = jnp.ones((8, 16), jnp.float32) * 50.0
    y = jnp.ones((8, 1), jnp.float32)
    w = jnp.ones((16, 1), jnp.float32)
    g = lk.logistic_grad(x, y, w, lam=0.0)
    assert bool(jnp.all(jnp.isfinite(g)))
    loss, g2 = lk.logistic_loss_and_grad(x, y, w, lam=0.0)
    assert bool(jnp.isfinite(loss))
    assert bool(jnp.all(jnp.isfinite(g2)))


def test_grad_zero_weights_closed_form():
    """At w=0 every sigmoid is 1/2, so grad = -X^T y / (2B)."""
    x, y, _ = _logreg_case(64, 32, 11)
    w = jnp.zeros((32, 1), jnp.float32)
    got = lk.logistic_grad(x, y, w, lam=0.0)
    want = -(x.T @ y) / (2 * x.shape[0])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# attention kernel
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    s=st.integers(min_value=1, max_value=128),
    dh=st.sampled_from([4, 8, 16, 32, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_attention_matches_ref(s, dh, seed):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k0, (s, dh), jnp.float32)
    k = jax.random.normal(k1, (s, dh), jnp.float32)
    v = jax.random.normal(k2, (s, dh), jnp.float32)
    got = attention_kernel.attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_attention_causality():
    """Output at position t must not depend on keys/values after t."""
    s, dh = 32, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (s, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (s, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (s, dh))
    o1 = attention_kernel.attention(q, k, v)
    # Perturb the *future* half of K and V; first half of output must not move.
    k2 = k.at[s // 2 :].add(100.0)
    v2 = v.at[s // 2 :].add(-55.0)
    o2 = attention_kernel.attention(q, k2, v2)
    np.testing.assert_allclose(o1[: s // 2], o2[: s // 2], rtol=1e-6, atol=1e-6)
    assert float(jnp.max(jnp.abs(o1[s // 2 :] - o2[s // 2 :]))) > 1e-3


def test_attention_first_row_is_v0():
    """Causal row 0 attends only to key 0 → output row 0 == v[0]."""
    s, dh = 16, 8
    q = jax.random.normal(jax.random.PRNGKey(3), (s, dh))
    k = jax.random.normal(jax.random.PRNGKey(4), (s, dh))
    v = jax.random.normal(jax.random.PRNGKey(5), (s, dh))
    o = attention_kernel.attention(q, k, v)
    np.testing.assert_allclose(o[0], v[0], rtol=1e-5, atol=1e-6)


def test_attention_vjp_matches_ref_vjp():
    s, dh = 48, 16
    q = jax.random.normal(jax.random.PRNGKey(6), (s, dh))

    def f_kernel(q):
        return jnp.sum(attention_kernel.attention(q, q, q) ** 2)

    def f_ref(q):
        return jnp.sum(ref.attention_ref(q, q, q) ** 2)

    g1 = jax.grad(f_kernel)(q)
    g2 = jax.grad(f_ref)(q)
    np.testing.assert_allclose(g1, g2, rtol=1e-3, atol=1e-4)


@settings(**SETTINGS)
@given(
    n=st.integers(min_value=1, max_value=6),
    s=st.sampled_from([8, 24, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_attention_vmap_batches(n, s, seed):
    dh = 16
    q = jax.random.normal(jax.random.PRNGKey(seed), (n, s, dh))
    got = jax.vmap(attention_kernel.attention)(q, q, q)
    want = jax.vmap(ref.attention_ref)(q, q, q)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)
